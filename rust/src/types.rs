//! Core value types shared by the scheduler, simulator and PJRT backend.
//!
//! The unit vocabulary follows the paper / OpenCL: the global index space
//! (`gws` work-items) is partitioned into *work-groups* of `lws` items;
//! schedulers deal exclusively in work-groups (the paper's `G_r` is the
//! count of pending work-groups), devices expand groups back into items.



/// Index of a device within the engine's device table.
pub type DeviceId = usize;

/// A half-open range of work-groups `[begin, end)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GroupRange {
    pub begin: u64,
    pub end: u64,
}

impl GroupRange {
    pub fn new(begin: u64, end: u64) -> Self {
        debug_assert!(begin <= end, "invalid GroupRange {begin}..{end}");
        Self { begin, end }
    }

    #[inline]
    pub fn len(&self) -> u64 {
        self.end - self.begin
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.begin == self.end
    }

    /// Expand to work-items for a given local work size.
    #[inline]
    pub fn items(&self, lws: u32) -> ItemRange {
        ItemRange {
            begin: self.begin * lws as u64,
            end: self.end * lws as u64,
        }
    }
}

/// A half-open range of work-items `[begin, end)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ItemRange {
    pub begin: u64,
    pub end: u64,
}

impl ItemRange {
    pub fn new(begin: u64, end: u64) -> Self {
        debug_assert!(begin <= end);
        Self { begin, end }
    }

    #[inline]
    pub fn len(&self) -> u64 {
        self.end - self.begin
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.begin == self.end
    }
}

/// One scheduler grant: a contiguous run of work-groups assigned to a
/// device.  `seq` is the global issue order (the paper's package launch
/// sequence — Static delivery order is visible through it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Package {
    pub seq: u64,
    pub device: DeviceId,
    pub groups: GroupRange,
}

/// The three device classes of the paper's commodity testbed
/// (AMD A10-7850K APU: 4-CU CPU + 8-CU R7 iGPU; NVIDIA GTX 950 dGPU).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceClass {
    Cpu,
    IGpu,
    DGpu,
}

impl DeviceClass {
    pub fn label(&self) -> &'static str {
        match self {
            DeviceClass::Cpu => "CPU",
            DeviceClass::IGpu => "iGPU",
            DeviceClass::DGpu => "GPU",
        }
    }

    /// Devices sharing main memory with the host (the paper's CPU + iGPU
    /// on the Kaveri APU) can elide bulk copies under the *buffers*
    /// optimization.
    pub fn shares_host_memory(&self) -> bool {
        !matches!(self, DeviceClass::DGpu)
    }
}

/// Static description of one device visible to the engine.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    pub class: DeviceClass,
    /// Relative computing power estimate handed to the schedulers (the
    /// paper's `P_i`).  Normalized against the dGPU = 1.0.
    pub power: f64,
}

/// A subset of a [`DevicePool`], as a bitmask over *pool* device indices.
/// Pipeline stages carry one per stage so independent DAG branches can
/// co-execute on disjoint subsets of the machine's device roster.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DeviceMask {
    bits: u64,
}

impl DeviceMask {
    /// Pool ids are bit positions in a u64.
    pub const MAX_DEVICES: usize = 64;

    /// No devices (the identity of [`DeviceMask::union`]).
    pub fn empty() -> Self {
        Self { bits: 0 }
    }

    /// The first `n` pool devices (the full pool for a pool of size `n`).
    pub fn all(n: usize) -> Self {
        assert!((1..=Self::MAX_DEVICES).contains(&n), "pool size {n} out of range");
        Self { bits: if n == 64 { u64::MAX } else { (1u64 << n) - 1 } }
    }

    /// Exactly one pool device.
    pub fn single(id: DeviceId) -> Self {
        assert!(id < Self::MAX_DEVICES, "device id {id} out of range");
        Self { bits: 1u64 << id }
    }

    /// The given pool devices (duplicates are harmless).
    pub fn from_indices(ids: &[DeviceId]) -> Self {
        let mut mask = Self::empty();
        for &id in ids {
            mask = mask.union(Self::single(id));
        }
        mask
    }

    #[inline]
    pub fn contains(&self, id: DeviceId) -> bool {
        id < Self::MAX_DEVICES && self.bits & (1u64 << id) != 0
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.bits == 0
    }

    /// Number of selected devices.
    #[inline]
    pub fn count(&self) -> usize {
        self.bits.count_ones() as usize
    }

    #[inline]
    pub fn union(&self, other: Self) -> Self {
        Self { bits: self.bits | other.bits }
    }

    #[inline]
    pub fn intersects(&self, other: Self) -> bool {
        self.bits & other.bits != 0
    }

    /// The devices of `self` that are not in `other`.
    #[inline]
    pub fn difference(&self, other: Self) -> Self {
        Self { bits: self.bits & !other.bits }
    }

    #[inline]
    pub fn is_disjoint(&self, other: Self) -> bool {
        !self.intersects(other)
    }

    /// Selected pool ids, ascending.
    pub fn indices(&self) -> Vec<DeviceId> {
        (0..Self::MAX_DEVICES).filter(|&i| self.contains(i)).collect()
    }

    /// True when every device of `self` is also in `other`.
    #[inline]
    pub fn is_subset_of(&self, other: Self) -> bool {
        self.bits & !other.bits == 0
    }

    /// All non-empty subsets of this mask (the mask-policy search space),
    /// in the deterministic sub-bitmask enumeration order: the full mask
    /// first, then numerically descending.  `2^count - 1` entries.
    pub fn subsets(&self) -> Vec<DeviceMask> {
        let mut out = Vec::new();
        let mut sub = self.bits;
        while sub != 0 {
            out.push(DeviceMask { bits: sub });
            sub = (sub - 1) & self.bits;
        }
        out
    }

    /// Highest selected pool id + 1 (0 for the empty mask) — the minimum
    /// pool size this mask is valid against.
    pub fn span(&self) -> usize {
        Self::MAX_DEVICES - self.bits.leading_zeros() as usize
    }

    /// Parse one mask against a pool's device classes.  Tokens are
    /// separated by `+` or `,`; each is `all`, a class name (`cpu`,
    /// `igpu`, `gpu` — selecting every pool device of that class), or a
    /// decimal pool index (`0`, `2`).  Errors on unknown tokens,
    /// out-of-range indices, classes absent from the pool, and empty
    /// masks.
    pub fn parse(s: &str, classes: &[DeviceClass]) -> Result<Self, String> {
        let mut mask = Self::empty();
        for token in s.split(['+', ',']) {
            let token = token.trim().to_lowercase();
            if token.is_empty() {
                return Err(format!("empty device token in mask '{s}'"));
            }
            if token == "all" {
                mask = mask.union(Self::all(classes.len()));
                continue;
            }
            let class = match token.as_str() {
                "cpu" => Some(DeviceClass::Cpu),
                "igpu" => Some(DeviceClass::IGpu),
                "gpu" | "dgpu" => Some(DeviceClass::DGpu),
                _ => None,
            };
            if let Some(class) = class {
                let hits: Vec<DeviceId> = classes
                    .iter()
                    .enumerate()
                    .filter(|&(_, &c)| c == class)
                    .map(|(i, _)| i)
                    .collect();
                if hits.is_empty() {
                    return Err(format!("no '{token}' device in the pool"));
                }
                mask = mask.union(Self::from_indices(&hits));
            } else if let Ok(id) = token.parse::<usize>() {
                if id >= classes.len() {
                    return Err(format!(
                        "device index {id} out of range (pool has {} devices)",
                        classes.len()
                    ));
                }
                mask = mask.union(Self::single(id));
            } else {
                return Err(format!("unknown device '{token}' (all|cpu|igpu|gpu|index)"));
            }
        }
        if mask.is_empty() {
            return Err(format!("mask '{s}' selects no devices"));
        }
        Ok(mask)
    }

    /// Human-readable label against a pool's classes, e.g. `cpu+igpu`.
    pub fn label(&self, classes: &[DeviceClass]) -> String {
        let names: Vec<String> = self
            .indices()
            .into_iter()
            .map(|i| match classes.get(i) {
                Some(c) => c.label().to_lowercase(),
                None => i.to_string(),
            })
            .collect();
        names.join("+")
    }
}

/// The machine's full device roster with stable pool-wide device ids.
/// Every pipeline trace, fault-injection target and energy account is
/// indexed by pool id; stages run on [`DeviceView`]s cut from the pool by
/// a [`DeviceMask`].
#[derive(Debug, Clone)]
pub struct DevicePool {
    devices: Vec<DeviceSpec>,
}

impl DevicePool {
    pub fn new(devices: Vec<DeviceSpec>) -> Self {
        assert!(!devices.is_empty(), "a device pool needs at least one device");
        assert!(devices.len() <= DeviceMask::MAX_DEVICES, "pool too large");
        Self { devices }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.devices.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn specs(&self) -> &[DeviceSpec] {
        &self.devices
    }

    pub fn classes(&self) -> Vec<DeviceClass> {
        self.devices.iter().map(|d| d.class).collect()
    }

    pub fn full_mask(&self) -> DeviceMask {
        DeviceMask::all(self.len())
    }

    /// Cut the masked view out of the pool.  Panics on empty masks and on
    /// masks that reference devices beyond the pool.
    pub fn view(&self, mask: DeviceMask) -> DeviceView {
        assert!(!mask.is_empty(), "a stage mask must select at least one device");
        assert!(
            mask.span() <= self.len(),
            "mask references device {} but the pool has {}",
            mask.span() - 1,
            self.len()
        );
        let pool_ids = mask.indices();
        let devices = pool_ids.iter().map(|&i| self.devices[i].clone()).collect();
        DeviceView { pool_ids, devices }
    }
}

/// A masked slice of a [`DevicePool`]: the devices one pipeline stage
/// runs on.  `pool_ids[slot]` maps the stage-local device slot back to
/// its pool id (traces, fault injection and energy stay pool-indexed).
#[derive(Debug, Clone)]
pub struct DeviceView {
    pub pool_ids: Vec<DeviceId>,
    pub devices: Vec<DeviceSpec>,
}

/// Execution mode of a run (paper §V-B / Fig. 6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Whole program: initialization + ROI + release.
    Binary,
    /// Region of interest only: transfers + kernel compute.
    Roi,
}

/// A time budget for the ROI of one run — the paper's *time-constrained
/// scenario* knob.  The deadline is relative to ROI start; schedulers that
/// are deadline-aware (see `scheduler::adaptive`) adapt their package
/// sizing to the remaining budget.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimeBudget {
    /// ROI deadline, in seconds.
    pub deadline_s: f64,
}

impl TimeBudget {
    pub fn new(deadline_s: f64) -> Self {
        assert!(
            deadline_s > 0.0 && deadline_s.is_finite(),
            "deadline must be positive and finite, got {deadline_s}"
        );
        Self { deadline_s }
    }

    /// Remaining budget at `now_s` (clamped at zero once overshot).
    #[inline]
    pub fn remaining(&self, now_s: f64) -> f64 {
        (self.deadline_s - now_s).max(0.0)
    }

    /// Fraction of the budget still ahead at `now_s`: 1 at ROI start,
    /// 0 at (and after) the deadline.
    #[inline]
    pub fn urgency(&self, now_s: f64) -> f64 {
        (self.remaining(now_s) / self.deadline_s).clamp(0.0, 1.0)
    }

    /// Verdict for a finished ROI.
    pub fn verdict(&self, roi_s: f64) -> DeadlineVerdict {
        DeadlineVerdict {
            deadline_s: self.deadline_s,
            roi_s,
            met: roi_s <= self.deadline_s,
            slack_s: self.deadline_s - roi_s,
        }
    }
}

/// Outcome of one run against its [`TimeBudget`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeadlineVerdict {
    pub deadline_s: f64,
    pub roi_s: f64,
    pub met: bool,
    /// Positive = finished early; negative = overshoot.
    pub slack_s: f64,
}

/// A sustained-rate budget for streaming mode — the throughput
/// counterpart of [`TimeBudget`].  A stream of long-running operators
/// has no makespan to judge; instead it must *hold* `rate_hz` items/s,
/// measured over boundary-aligned windows of `window_s` seconds while
/// it runs and over the whole active span at stream end.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ThroughputBudget {
    /// Required sustained rate, in items/s.
    pub rate_hz: f64,
    /// Throughput-measurement window, in seconds: live verdicts and the
    /// mask/budget re-evaluations happen at multiples of this.
    pub window_s: f64,
}

impl ThroughputBudget {
    pub fn new(rate_hz: f64, window_s: f64) -> Self {
        assert!(
            rate_hz > 0.0 && rate_hz.is_finite(),
            "throughput rate must be positive and finite, got {rate_hz}"
        );
        assert!(
            window_s > 0.0 && window_s.is_finite(),
            "throughput window must be positive and finite, got {window_s}"
        );
        Self { rate_hz, window_s }
    }

    /// Whether an observed rate holds the budget (tolerating one part in
    /// 1e12 of float noise from the `items / span` division).
    #[inline]
    pub fn holds(&self, achieved_hz: f64) -> bool {
        achieved_hz >= self.rate_hz * (1.0 - 1e-12)
    }

    /// Verdict for an observed sustained rate.
    pub fn verdict(&self, achieved_hz: f64) -> ThroughputVerdict {
        ThroughputVerdict {
            rate_hz: self.rate_hz,
            window_s: self.window_s,
            achieved_hz,
            met: self.holds(achieved_hz),
            margin_hz: achieved_hz - self.rate_hz,
        }
    }
}

/// Outcome of a stream (or one of its windows) against its
/// [`ThroughputBudget`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ThroughputVerdict {
    pub rate_hz: f64,
    pub window_s: f64,
    pub achieved_hz: f64,
    pub met: bool,
    /// Positive = sustained above the required rate; negative = deficit.
    pub margin_hz: f64,
}

/// Shape of a streaming run: an unbounded source feeding the operator
/// chain at `offered_hz`, bounded inter-stage queues of `queue_cap`
/// items (a full downstream queue stalls the producer's next
/// iteration), judged by a sustained-rate [`ThroughputBudget`] instead
/// of a makespan deadline.  `n_items` bounds the simulation horizon —
/// the source is conceptually unbounded, the simulation is not.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StreamSpec {
    /// Source emission rate, in items/s (item `k` enters the source
    /// queue at `k / offered_hz`).
    pub offered_hz: f64,
    /// Items the source emits over the simulated horizon.
    pub n_items: usize,
    /// Capacity of every bounded inter-stage queue (the source queue in
    /// front of the first operator is unbounded — overload piles up
    /// there and shows as a missed throughput verdict, not as drops).
    pub queue_cap: usize,
    /// The sustained-rate deadline the stream is judged by.
    pub budget: ThroughputBudget,
}

impl StreamSpec {
    pub fn new(
        offered_hz: f64,
        n_items: usize,
        queue_cap: usize,
        budget: ThroughputBudget,
    ) -> Self {
        assert!(
            offered_hz > 0.0 && offered_hz.is_finite(),
            "source rate must be positive and finite, got {offered_hz}"
        );
        assert!(n_items >= 1, "a stream needs at least one item");
        assert!(queue_cap >= 1, "inter-stage queues need room for at least one item");
        Self { offered_hz, n_items, queue_cap, budget }
    }
}

/// How a pipeline's **global** [`TimeBudget`] is split into per-iteration
/// sub-budgets (the ROADMAP's "per-iteration sub-budgets, carry-over
/// slack" item).  Sub-deadlines are *absolute* instants on the cumulative
/// pipeline ROI clock, so the deadline-aware schedulers can be re-armed
/// each iteration against the pipeline clock instead of a per-iteration
/// zero.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BudgetPolicy {
    /// Every iteration gets an equal slice: the i-th sub-deadline sits at
    /// `(i + 1) / N` of the global deadline, regardless of how earlier
    /// iterations actually fared.
    EvenSplit,
    /// Equal nominal shares, but slack left over by earlier iterations is
    /// carried forward (and a late pipeline is re-planned from the current
    /// clock): the sub-deadline never precedes EvenSplit's for the same
    /// clock trajectory.
    CarryOverSlack,
    /// Every iteration may spend the whole remaining global budget — the
    /// front of the pipeline is never throttled by a slice.
    GreedyFrontload,
    /// Budget split proportional to each iteration's position on its
    /// *critical path* through the stage DAG: iteration `j` of a stage
    /// whose longest dependency chain holds `c` iterations before it and
    /// `d` after gets the sub-deadline `(c + j + 1) / (c + N + d)` of the
    /// deadline, so slack flows to the longest branch instead of the
    /// topological launch order.  Off-DAG callers (no per-stage chain
    /// information) fall back to [`BudgetPolicy::EvenSplit`]'s slices.
    CriticalPath,
}

impl BudgetPolicy {
    pub const ALL: [BudgetPolicy; 4] = [
        BudgetPolicy::EvenSplit,
        BudgetPolicy::CarryOverSlack,
        BudgetPolicy::GreedyFrontload,
        BudgetPolicy::CriticalPath,
    ];

    /// Absolute sub-deadline (pipeline-ROI clock, seconds) for iteration
    /// `iter` of `total_iters`, starting at `clock_s`, where
    /// `prev_deadline_s` is the previous iteration's sub-deadline (0 for
    /// the first).  `roi_deadline_s` is the global ROI-scope deadline.
    pub fn sub_deadline(
        &self,
        roi_deadline_s: f64,
        total_iters: u32,
        iter: u32,
        clock_s: f64,
        prev_deadline_s: f64,
    ) -> f64 {
        debug_assert!(total_iters >= 1 && iter < total_iters);
        let share = roi_deadline_s / total_iters as f64;
        match self {
            BudgetPolicy::EvenSplit => share * (iter + 1) as f64,
            BudgetPolicy::CarryOverSlack => prev_deadline_s.max(clock_s) + share,
            BudgetPolicy::GreedyFrontload => roi_deadline_s,
            // Without DAG chain information the critical path degenerates
            // to the iteration sequence itself — even slices.  The
            // pipeline engine overrides this with the per-stage
            // critical-path fractions it computes at prepare time.
            BudgetPolicy::CriticalPath => share * (iter + 1) as f64,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            BudgetPolicy::EvenSplit => "even-split",
            BudgetPolicy::CarryOverSlack => "carry-over-slack",
            BudgetPolicy::GreedyFrontload => "greedy-frontload",
            BudgetPolicy::CriticalPath => "critical-path",
        }
    }

    /// Parse a CLI spelling (full label or short alias).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_lowercase().as_str() {
            "even" | "even-split" | "evensplit" => Some(BudgetPolicy::EvenSplit),
            "carry" | "carry-over-slack" | "carryoverslack" => Some(BudgetPolicy::CarryOverSlack),
            "greedy" | "greedy-frontload" | "greedyfrontload" => {
                Some(BudgetPolicy::GreedyFrontload)
            }
            "critical" | "critical-path" | "criticalpath" => Some(BudgetPolicy::CriticalPath),
            _ => None,
        }
    }
}

/// Energy policy of a time-constrained pipeline (the ROADMAP's
/// "race-to-idle vs stretch-to-deadline" energy-aware Adaptive variants).
/// The policy modulates the Adaptive scheduler's pessimism: racing keeps
/// the configured guard (finish as early as possible, then idle), while
/// stretching raises it so grants shrink earlier and finish times cluster
/// in front of the deadline instead of straggling past it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EnergyPolicy {
    /// Finish as early as possible and let the devices idle afterwards:
    /// the configured pessimism is used unchanged.
    RaceToIdle,
    /// Use the whole sub-budget: pessimism is raised to at least 0.55, so
    /// the completion caps engage sooner and overshoot risk drops at the
    /// price of more (smaller) packages.
    StretchToDeadline,
}

impl EnergyPolicy {
    pub const ALL: [EnergyPolicy; 2] = [EnergyPolicy::RaceToIdle, EnergyPolicy::StretchToDeadline];

    /// The effective Adaptive pessimism under this policy.
    pub fn pessimism(&self, base: f64) -> f64 {
        match self {
            EnergyPolicy::RaceToIdle => base,
            // Strictly below 1.0 (AdaptiveParams::validate's bound).
            EnergyPolicy::StretchToDeadline => base.max(0.55),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            EnergyPolicy::RaceToIdle => "race-to-idle",
            EnergyPolicy::StretchToDeadline => "stretch-to-deadline",
        }
    }

    /// Parse a CLI spelling (full label or short alias).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_lowercase().as_str() {
            "race" | "race-to-idle" | "racetoidle" => Some(EnergyPolicy::RaceToIdle),
            "stretch" | "stretch-to-deadline" | "stretchtodeadline" => {
                Some(EnergyPolicy::StretchToDeadline)
            }
            _ => None,
        }
    }
}

/// How each pipeline stage's device mask is chosen (the ROADMAP's
/// "energy-aware device *subset* selection under loose deadlines" item).
///
/// `Fixed` takes the stage's spec mask verbatim — the PR-3 behaviour and
/// the bit-identical baseline.  The other policies search the non-empty
/// subsets of the spec mask before the stage launches, predicting
/// (time, joules) per subset from the scheduler's own `P_i` estimate
/// path and the [`crate::cldriver::PowerModel`], including the
/// inter-stage transfer deltas a mask change induces on the stage's
/// dependency edges.  This is the race-to-idle vs. device-shedding
/// trade-off of the EngineCL energy work (arXiv:1805.02755): the most
/// energy-efficient configuration is frequently a strict subset of the
/// available devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MaskPolicy {
    /// Use the spec mask verbatim (no search).
    Fixed,
    /// Cheapest predicted marginal energy, deadline-blind (still charged
    /// for extending the stage beyond the committed schedule horizon).
    MinEnergy,
    /// Earliest predicted stage finish — sheds only when a subset starts
    /// earlier (fewer busy devices to wait for) or dodges an inter-stage
    /// transfer by matching its producer's mask.
    MinTime,
    /// Cheapest predicted energy among the subsets whose predicted
    /// per-iteration sub-deadline hits are no fewer than the spec mask's,
    /// falling back to the full spec mask when no subset qualifies.
    EnergyUnderDeadline,
}

impl MaskPolicy {
    pub const ALL: [MaskPolicy; 4] = [
        MaskPolicy::Fixed,
        MaskPolicy::MinEnergy,
        MaskPolicy::MinTime,
        MaskPolicy::EnergyUnderDeadline,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            MaskPolicy::Fixed => "fixed",
            MaskPolicy::MinEnergy => "min-energy",
            MaskPolicy::MinTime => "min-time",
            MaskPolicy::EnergyUnderDeadline => "energy-under-deadline",
        }
    }

    /// Parse a CLI spelling (full label or short alias).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_lowercase().as_str() {
            "fixed" | "spec" => Some(MaskPolicy::Fixed),
            "min-energy" | "minenergy" | "energy" => Some(MaskPolicy::MinEnergy),
            "min-time" | "mintime" | "time" => Some(MaskPolicy::MinTime),
            "energy-under-deadline" | "energyunderdeadline" | "eud" => {
                Some(MaskPolicy::EnergyUnderDeadline)
            }
            _ => None,
        }
    }
}

/// How co-execution retention (shared-DDR / host-thread interference) is
/// scoped when pipeline stages run concurrently on the device pool.
///
/// `View` is the legacy model: each stage prices retention against the
/// size of its *own* device view, so two branches co-executing on
/// disjoint masks pay zero cross-branch interference — optimistic, per
/// the oneAPI co-execution study (arXiv:2106.01726) contention grows
/// with the number of simultaneously active devices.  `Pool` derives
/// retention from the number of *concurrently active* devices on the
/// whole pool, recomputed at stage launch/finish events (piecewise-
/// constant windows on the cumulative pipeline clock).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ContentionModel {
    /// Retention scoped to each stage's own device view (legacy; the
    /// bit-identical baseline).
    #[default]
    View,
    /// Retention derived from the pool's concurrently-active device
    /// count (cross-branch contention).
    Pool,
}

impl ContentionModel {
    pub const ALL: [ContentionModel; 2] = [ContentionModel::View, ContentionModel::Pool];

    pub fn label(&self) -> &'static str {
        match self {
            ContentionModel::View => "view",
            ContentionModel::Pool => "pool",
        }
    }

    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_lowercase().as_str() {
            "view" | "stage" | "legacy" => Some(ContentionModel::View),
            "pool" | "cross-branch" | "crossbranch" => Some(ContentionModel::Pool),
            _ => None,
        }
    }
}

/// Deadline-aware admission control for the multi-tenant fleet driver
/// (`sim::tenancy`): what happens when a new pipeline request arrives at
/// a shared device pool.  Decisions are made against the *predicted*
/// completion of the request's stage chain (the mask predictor's own
/// time model, priced against the pool's committed schedule), so a
/// request is never admitted on hope alone under the gating policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    /// Admit every request unconditionally (open-loop baseline).
    #[default]
    Accept,
    /// Reject a request at arrival when its predicted chain completion
    /// misses its deadline under the pool's current commitments.
    RejectInfeasible,
    /// Hold an infeasible arrival in a queue and re-evaluate it whenever
    /// a stage completes; permanently reject once even an idle pool
    /// could no longer meet its deadline.
    QueueUntilFeasible,
    /// Like `RejectInfeasible`, but an infeasible arrival may instead
    /// shed the not-yet-started request with the lowest *weighted*
    /// slack (predicted slack scaled by the request's `priority`
    /// weight), protecting the requests most likely to hit their
    /// deadlines.  An arrival that is its own victim is recorded as
    /// `Shed`, not `Rejected` — it *was* the policy's victim.  A
    /// reserved-share guard caps how many of a tenant's requests other
    /// tenants may displace, so a high-priority tenant cannot starve
    /// the pool.  Running stages are never shed (but see
    /// [`PreemptionPolicy`] for iteration-boundary preemption).
    ShedLowestSlack,
}

impl AdmissionPolicy {
    pub const ALL: [AdmissionPolicy; 4] = [
        AdmissionPolicy::Accept,
        AdmissionPolicy::RejectInfeasible,
        AdmissionPolicy::QueueUntilFeasible,
        AdmissionPolicy::ShedLowestSlack,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            AdmissionPolicy::Accept => "accept",
            AdmissionPolicy::RejectInfeasible => "reject-infeasible",
            AdmissionPolicy::QueueUntilFeasible => "queue-until-feasible",
            AdmissionPolicy::ShedLowestSlack => "shed-lowest-slack",
        }
    }

    /// Parse a CLI spelling (full label or short alias).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_lowercase().as_str() {
            "accept" | "always" => Some(AdmissionPolicy::Accept),
            "reject-infeasible" | "rejectinfeasible" | "reject" => {
                Some(AdmissionPolicy::RejectInfeasible)
            }
            "queue-until-feasible" | "queueuntilfeasible" | "queue" => {
                Some(AdmissionPolicy::QueueUntilFeasible)
            }
            "shed-lowest-slack" | "shedlowestslack" | "shed" => {
                Some(AdmissionPolicy::ShedLowestSlack)
            }
            _ => None,
        }
    }
}

/// Whether a running stage may be displaced by a higher-priority
/// request in the multi-tenant fleet driver (`sim::tenancy`).
///
/// Preemption is only ever considered at *iteration boundaries*: a
/// stage's iteration is the engine's atomic unit of work, so the event
/// core never tears a package mid-flight.  A preempted stage releases
/// its devices, re-enters the launch queue, and on relaunch pays an
/// explicit re-scatter transfer (its working set is gathered off the
/// old mask and scattered onto the relaunch mask — the preemptor is
/// assumed to have evicted the resident buffers).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PreemptionPolicy {
    /// Never preempt: a launched stage runs to completion (the
    /// bit-identical legacy behavior).
    #[default]
    Never,
    /// At each iteration boundary, a running stage yields its devices
    /// when a strictly-higher-priority admitted request has a
    /// dependency-ready stage blocked only by them.
    IterationBoundary,
}

impl PreemptionPolicy {
    pub const ALL: [PreemptionPolicy; 2] =
        [PreemptionPolicy::Never, PreemptionPolicy::IterationBoundary];

    pub fn label(&self) -> &'static str {
        match self {
            PreemptionPolicy::Never => "never",
            PreemptionPolicy::IterationBoundary => "iteration-boundary",
        }
    }

    /// Parse a CLI spelling (full label or short alias).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_lowercase().as_str() {
            "never" | "none" | "off" => Some(PreemptionPolicy::Never),
            "iteration-boundary" | "iterationboundary" | "iter-boundary" | "iter" => {
                Some(PreemptionPolicy::IterationBoundary)
            }
            _ => None,
        }
    }
}

/// How the scheduler's computing-power estimates `P_i` relate to the true
/// co-execution powers.  The paper profiles powers offline, so the
/// scheduler may run under estimation error; its headline 0.84 efficiency
/// is quoted under a *pessimistic* scenario.  The fastest device is the
/// normalization reference and is never skewed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EstimateScenario {
    /// Estimates equal the profiled co-execution powers.
    Exact,
    /// Slower devices look `err` faster than they really are, so the
    /// scheduler overcommits them.
    Optimistic { err: f64 },
    /// Slower devices look `err` slower than they really are, so the
    /// scheduler underuses them.
    Pessimistic { err: f64 },
}

impl EstimateScenario {
    /// Apply the skew to one device's true power; `is_reference` marks the
    /// fastest device.
    pub fn skew(&self, power: f64, is_reference: bool) -> f64 {
        if is_reference {
            return power;
        }
        match *self {
            EstimateScenario::Exact => power,
            EstimateScenario::Optimistic { err } => power * (1.0 + err.max(0.0)),
            EstimateScenario::Pessimistic { err } => power * (1.0 - err).max(0.05),
        }
    }

    pub fn label(&self) -> String {
        match self {
            EstimateScenario::Exact => "exact".into(),
            EstimateScenario::Optimistic { err } => format!("optimistic({err:.2})"),
            EstimateScenario::Pessimistic { err } => format!("pessimistic({err:.2})"),
        }
    }
}

/// The two runtime optimizations proposed in paper §III, plus the
/// pipeline engine's estimate-refinement extension.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Optimizations {
    /// Overlap platform/device discovery with Scheduler/Device thread
    /// preparation and reuse discovery structures.
    pub init_overlap: bool,
    /// Set buffer placement flags so same-main-memory devices map instead
    /// of copying.
    pub buffer_flags: bool,
    /// Pipeline extension: feed each stage's *measured* iteration
    /// throughput back into the `P_i` estimates arming the next
    /// iteration's scheduler, recovering from skewed offline profiles.
    pub estimate_refine: bool,
}

impl Optimizations {
    pub const NONE: Self =
        Self { init_overlap: false, buffer_flags: false, estimate_refine: false };
    pub const INIT: Self =
        Self { init_overlap: true, buffer_flags: false, estimate_refine: false };
    /// The paper's final runtime: both §III optimizations, no extensions.
    pub const ALL: Self =
        Self { init_overlap: true, buffer_flags: true, estimate_refine: false };

    pub fn with_estimate_refine(mut self, on: bool) -> Self {
        self.estimate_refine = on;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_range_expands_to_items() {
        let g = GroupRange::new(2, 5);
        assert_eq!(g.len(), 3);
        let items = g.items(128);
        assert_eq!(items.begin, 256);
        assert_eq!(items.end, 640);
        assert_eq!(items.len(), 384);
    }

    #[test]
    fn empty_ranges() {
        assert!(GroupRange::new(7, 7).is_empty());
        assert!(ItemRange::new(0, 0).is_empty());
        assert!(!GroupRange::new(0, 1).is_empty());
    }

    const TESTBED: [DeviceClass; 3] =
        [DeviceClass::Cpu, DeviceClass::IGpu, DeviceClass::DGpu];

    fn testbed_pool() -> DevicePool {
        DevicePool::new(
            TESTBED.iter().map(|&class| DeviceSpec { class, power: 1.0 }).collect(),
        )
    }

    #[test]
    fn mask_set_algebra() {
        let a = DeviceMask::from_indices(&[0, 1]);
        let b = DeviceMask::single(2);
        assert!(a.contains(0) && a.contains(1) && !a.contains(2));
        assert_eq!(a.count(), 2);
        assert!(a.is_disjoint(b) && !a.intersects(b));
        let all = a.union(b);
        assert_eq!(all, DeviceMask::all(3));
        assert_eq!(all.indices(), vec![0, 1, 2]);
        assert_eq!(all.span(), 3);
        assert_eq!(all.difference(b), a);
        assert_eq!(a.difference(all), DeviceMask::empty());
        assert_eq!(a.difference(DeviceMask::empty()), a);
        assert!(DeviceMask::empty().is_empty());
        assert!(a.intersects(DeviceMask::single(1)));
    }

    #[test]
    fn mask_parse_accepts_classes_indices_and_all() {
        let c = &TESTBED;
        assert_eq!(DeviceMask::parse("all", c).unwrap(), DeviceMask::all(3));
        assert_eq!(DeviceMask::parse("cpu", c).unwrap(), DeviceMask::single(0));
        assert_eq!(DeviceMask::parse("gpu", c).unwrap(), DeviceMask::single(2));
        assert_eq!(
            DeviceMask::parse("cpu+igpu", c).unwrap(),
            DeviceMask::from_indices(&[0, 1])
        );
        assert_eq!(
            DeviceMask::parse("0,2", c).unwrap(),
            DeviceMask::from_indices(&[0, 2])
        );
        assert_eq!(DeviceMask::parse(" CPU + 2 ", c).unwrap().indices(), vec![0, 2]);
    }

    #[test]
    fn mask_parse_rejects_malformed_input() {
        let c = &TESTBED;
        assert!(DeviceMask::parse("", c).is_err());
        assert!(DeviceMask::parse("xpu", c).is_err());
        assert!(DeviceMask::parse("cpu+", c).is_err(), "trailing empty token");
        assert!(DeviceMask::parse("9", c).is_err(), "index beyond the pool");
        assert!(
            DeviceMask::parse("igpu", &[DeviceClass::Cpu]).is_err(),
            "class absent from the pool"
        );
    }

    #[test]
    fn mask_subset_relation_and_enumeration() {
        let spec = DeviceMask::from_indices(&[0, 2]);
        assert!(DeviceMask::single(0).is_subset_of(spec));
        assert!(spec.is_subset_of(spec));
        assert!(!DeviceMask::single(1).is_subset_of(spec));
        assert!(DeviceMask::empty().is_subset_of(spec));
        // Sub-bitmask enumeration: full mask first, non-empty, complete.
        let subs = spec.subsets();
        assert_eq!(subs.len(), 3);
        assert_eq!(subs[0], spec, "full mask enumerated first");
        assert!(subs.contains(&DeviceMask::single(0)));
        assert!(subs.contains(&DeviceMask::single(2)));
        assert!(subs.iter().all(|s| !s.is_empty() && s.is_subset_of(spec)));
        assert_eq!(DeviceMask::all(3).subsets().len(), 7);
        assert_eq!(DeviceMask::single(1).subsets(), vec![DeviceMask::single(1)]);
    }

    #[test]
    fn mask_policy_labels_parse_roundtrip() {
        for p in MaskPolicy::ALL {
            assert_eq!(MaskPolicy::parse(p.label()), Some(p));
        }
        assert_eq!(MaskPolicy::parse("EUD"), Some(MaskPolicy::EnergyUnderDeadline));
        assert_eq!(MaskPolicy::parse("time"), Some(MaskPolicy::MinTime));
        assert_eq!(MaskPolicy::parse("energy"), Some(MaskPolicy::MinEnergy));
        assert_eq!(MaskPolicy::parse("fastest"), None);
    }

    #[test]
    fn mask_labels_use_pool_classes() {
        let c = &TESTBED;
        assert_eq!(DeviceMask::from_indices(&[0, 1]).label(c), "cpu+igpu");
        assert_eq!(DeviceMask::single(2).label(c), "gpu");
    }

    #[test]
    fn pool_views_remap_to_pool_ids() {
        let pool = testbed_pool();
        assert_eq!(pool.len(), 3);
        assert_eq!(pool.full_mask(), DeviceMask::all(3));
        let v = pool.view(DeviceMask::from_indices(&[0, 2]));
        assert_eq!(v.pool_ids, vec![0, 2]);
        assert_eq!(v.devices.len(), 2);
        assert_eq!(v.devices[1].class, DeviceClass::DGpu);
        let full = pool.view(pool.full_mask());
        assert_eq!(full.pool_ids, vec![0, 1, 2]);
    }

    #[test]
    #[should_panic(expected = "mask references device")]
    fn pool_view_rejects_out_of_range_masks() {
        testbed_pool().view(DeviceMask::single(5));
    }

    #[test]
    #[should_panic(expected = "at least one device")]
    fn pool_view_rejects_empty_masks() {
        testbed_pool().view(DeviceMask::empty());
    }

    #[test]
    fn optimizations_refine_builder() {
        assert!(!Optimizations::ALL.estimate_refine, "paper runtime has no extension");
        let r = Optimizations::ALL.with_estimate_refine(true);
        assert!(r.estimate_refine && r.init_overlap && r.buffer_flags);
    }

    #[test]
    fn device_class_memory_sharing_matches_paper_testbed() {
        assert!(DeviceClass::Cpu.shares_host_memory());
        assert!(DeviceClass::IGpu.shares_host_memory());
        assert!(!DeviceClass::DGpu.shares_host_memory());
    }

    #[test]
    fn time_budget_urgency_and_remaining() {
        let b = TimeBudget::new(2.0);
        assert_eq!(b.remaining(0.0), 2.0);
        assert_eq!(b.remaining(1.5), 0.5);
        assert_eq!(b.remaining(3.0), 0.0);
        assert!((b.urgency(0.0) - 1.0).abs() < 1e-12);
        assert!((b.urgency(1.0) - 0.5).abs() < 1e-12);
        assert_eq!(b.urgency(2.5), 0.0);
    }

    #[test]
    fn time_budget_verdict_signs() {
        let b = TimeBudget::new(1.0);
        let hit = b.verdict(0.8);
        assert!(hit.met && hit.slack_s > 0.0);
        let miss = b.verdict(1.2);
        assert!(!miss.met && miss.slack_s < 0.0);
        assert!((miss.slack_s + 0.2).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "deadline must be positive")]
    fn time_budget_rejects_nonpositive() {
        TimeBudget::new(0.0);
    }

    #[test]
    fn throughput_budget_verdict_signs_and_tolerance() {
        let b = ThroughputBudget::new(10.0, 0.5);
        let hit = b.verdict(12.0);
        assert!(hit.met && (hit.margin_hz - 2.0).abs() < 1e-12);
        assert_eq!(hit.rate_hz, 10.0);
        assert_eq!(hit.window_s, 0.5);
        let miss = b.verdict(9.0);
        assert!(!miss.met && (miss.margin_hz + 1.0).abs() < 1e-12);
        // Exactly-at-rate holds, including one part in 1e12 of float
        // noise below it (the items/span division).
        assert!(b.holds(10.0));
        assert!(b.holds(10.0 * (1.0 - 1e-13)));
        assert!(!b.holds(10.0 * (1.0 - 1e-9)));
    }

    #[test]
    #[should_panic(expected = "throughput rate must be positive")]
    fn throughput_budget_rejects_nonpositive_rate() {
        ThroughputBudget::new(0.0, 1.0);
    }

    #[test]
    #[should_panic(expected = "throughput window must be positive")]
    fn throughput_budget_rejects_nonfinite_window() {
        ThroughputBudget::new(1.0, f64::INFINITY);
    }

    #[test]
    fn stream_spec_validates_its_shape() {
        let b = ThroughputBudget::new(4.0, 1.0);
        let s = StreamSpec::new(5.0, 32, 3, b);
        assert_eq!(s.n_items, 32);
        assert_eq!(s.queue_cap, 3);
        assert_eq!(s.budget, b);
    }

    #[test]
    #[should_panic(expected = "at least one item")]
    fn stream_spec_rejects_empty_stream() {
        StreamSpec::new(1.0, 0, 1, ThroughputBudget::new(1.0, 1.0));
    }

    #[test]
    #[should_panic(expected = "room for at least one item")]
    fn stream_spec_rejects_zero_queue_cap() {
        StreamSpec::new(1.0, 4, 0, ThroughputBudget::new(1.0, 1.0));
    }

    #[test]
    fn even_split_grid_is_fixed() {
        let p = BudgetPolicy::EvenSplit;
        for (iter, want) in [(0u32, 0.25), (1, 0.5), (2, 0.75), (3, 1.0)] {
            // The grid ignores both the clock and the previous deadline.
            let d = p.sub_deadline(1.0, 4, iter, 123.0, 456.0);
            assert!((d - want).abs() < 1e-12, "iter {iter}: {d}");
        }
    }

    #[test]
    fn carry_over_slack_dominates_even_split() {
        // For the same clock trajectory the carried sub-deadline is never
        // earlier than EvenSplit's slice boundary (proof by induction on
        // prev >= even_prev), so its per-iteration hit set is a superset.
        let mut rng_state = 88172645463325252u64;
        let mut next = move || {
            rng_state ^= rng_state << 13;
            rng_state ^= rng_state >> 7;
            rng_state ^= rng_state << 17;
            (rng_state >> 11) as f64 / (1u64 << 53) as f64
        };
        for _ in 0..200 {
            let d = 1.0 + next() * 9.0;
            let n = 1 + (next() * 12.0) as u32;
            let mut clock = 0.0;
            let mut prev_carry = 0.0;
            for iter in 0..n {
                let even = BudgetPolicy::EvenSplit.sub_deadline(d, n, iter, clock, 0.0);
                let carry =
                    BudgetPolicy::CarryOverSlack.sub_deadline(d, n, iter, clock, prev_carry);
                assert!(carry >= even - 1e-12, "iter {iter}: carry {carry} < even {even}");
                prev_carry = carry;
                clock += next() * 2.0 * d / n as f64; // early or late at random
            }
        }
    }

    #[test]
    fn carry_over_slack_replans_from_a_late_clock() {
        // On time: carry == even.  Late: the next slice starts at `now`.
        let p = BudgetPolicy::CarryOverSlack;
        let on_time = p.sub_deadline(2.0, 4, 1, 0.5, 0.5);
        assert!((on_time - 1.0).abs() < 1e-12);
        let late = p.sub_deadline(2.0, 4, 1, 0.9, 0.5);
        assert!((late - 1.4).abs() < 1e-12, "late re-plan: {late}");
    }

    #[test]
    fn greedy_frontload_always_offers_the_global_deadline() {
        for iter in 0..5 {
            let d = BudgetPolicy::GreedyFrontload.sub_deadline(3.0, 5, iter, 1.0, 2.0);
            assert_eq!(d, 3.0);
        }
    }

    #[test]
    fn policy_labels_parse_roundtrip() {
        for p in BudgetPolicy::ALL {
            assert_eq!(BudgetPolicy::parse(p.label()), Some(p));
        }
        assert_eq!(BudgetPolicy::parse("carry"), Some(BudgetPolicy::CarryOverSlack));
        assert_eq!(BudgetPolicy::parse("nope"), None);
        for e in EnergyPolicy::ALL {
            assert_eq!(EnergyPolicy::parse(e.label()), Some(e));
        }
        assert_eq!(EnergyPolicy::parse("race"), Some(EnergyPolicy::RaceToIdle));
        assert_eq!(EnergyPolicy::parse("nope"), None);
    }

    #[test]
    fn energy_policies_modulate_pessimism() {
        assert_eq!(EnergyPolicy::RaceToIdle.pessimism(0.25), 0.25);
        assert_eq!(EnergyPolicy::StretchToDeadline.pessimism(0.25), 0.55);
        // A harder configured guard is never weakened by stretching.
        assert_eq!(EnergyPolicy::StretchToDeadline.pessimism(0.7), 0.7);
        assert!(EnergyPolicy::StretchToDeadline.pessimism(0.0) < 1.0);
    }

    #[test]
    fn contention_model_labels_parse_roundtrip() {
        for c in ContentionModel::ALL {
            assert_eq!(ContentionModel::parse(c.label()), Some(c));
        }
        assert_eq!(ContentionModel::default(), ContentionModel::View);
        assert_eq!(ContentionModel::parse("Pool"), Some(ContentionModel::Pool));
        assert_eq!(ContentionModel::parse("legacy"), Some(ContentionModel::View));
        assert_eq!(ContentionModel::parse("both"), None);
    }

    #[test]
    fn admission_policy_labels_parse_roundtrip() {
        for a in AdmissionPolicy::ALL {
            assert_eq!(AdmissionPolicy::parse(a.label()), Some(a));
        }
        assert_eq!(AdmissionPolicy::default(), AdmissionPolicy::Accept);
        assert_eq!(AdmissionPolicy::parse("reject"), Some(AdmissionPolicy::RejectInfeasible));
        assert_eq!(AdmissionPolicy::parse("queue"), Some(AdmissionPolicy::QueueUntilFeasible));
        assert_eq!(AdmissionPolicy::parse("Shed"), Some(AdmissionPolicy::ShedLowestSlack));
        assert_eq!(AdmissionPolicy::parse("drop"), None);
    }

    #[test]
    fn estimate_scenarios_skew_non_reference_only() {
        let p = 0.4;
        for est in [
            EstimateScenario::Exact,
            EstimateScenario::Optimistic { err: 0.3 },
            EstimateScenario::Pessimistic { err: 0.3 },
        ] {
            assert_eq!(est.skew(p, true), p, "reference device never skewed");
        }
        assert_eq!(EstimateScenario::Exact.skew(p, false), p);
        assert!(EstimateScenario::Optimistic { err: 0.3 }.skew(p, false) > p);
        assert!(EstimateScenario::Pessimistic { err: 0.3 }.skew(p, false) < p);
        // Extreme pessimism never zeroes a power (scheduler needs P_i > 0).
        assert!(EstimateScenario::Pessimistic { err: 2.0 }.skew(p, false) > 0.0);
    }
}

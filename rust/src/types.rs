//! Core value types shared by the scheduler, simulator and PJRT backend.
//!
//! The unit vocabulary follows the paper / OpenCL: the global index space
//! (`gws` work-items) is partitioned into *work-groups* of `lws` items;
//! schedulers deal exclusively in work-groups (the paper's `G_r` is the
//! count of pending work-groups), devices expand groups back into items.



/// Index of a device within the engine's device table.
pub type DeviceId = usize;

/// A half-open range of work-groups `[begin, end)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GroupRange {
    pub begin: u64,
    pub end: u64,
}

impl GroupRange {
    pub fn new(begin: u64, end: u64) -> Self {
        debug_assert!(begin <= end, "invalid GroupRange {begin}..{end}");
        Self { begin, end }
    }

    #[inline]
    pub fn len(&self) -> u64 {
        self.end - self.begin
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.begin == self.end
    }

    /// Expand to work-items for a given local work size.
    #[inline]
    pub fn items(&self, lws: u32) -> ItemRange {
        ItemRange {
            begin: self.begin * lws as u64,
            end: self.end * lws as u64,
        }
    }
}

/// A half-open range of work-items `[begin, end)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ItemRange {
    pub begin: u64,
    pub end: u64,
}

impl ItemRange {
    pub fn new(begin: u64, end: u64) -> Self {
        debug_assert!(begin <= end);
        Self { begin, end }
    }

    #[inline]
    pub fn len(&self) -> u64 {
        self.end - self.begin
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.begin == self.end
    }
}

/// One scheduler grant: a contiguous run of work-groups assigned to a
/// device.  `seq` is the global issue order (the paper's package launch
/// sequence — Static delivery order is visible through it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Package {
    pub seq: u64,
    pub device: DeviceId,
    pub groups: GroupRange,
}

/// The three device classes of the paper's commodity testbed
/// (AMD A10-7850K APU: 4-CU CPU + 8-CU R7 iGPU; NVIDIA GTX 950 dGPU).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceClass {
    Cpu,
    IGpu,
    DGpu,
}

impl DeviceClass {
    pub fn label(&self) -> &'static str {
        match self {
            DeviceClass::Cpu => "CPU",
            DeviceClass::IGpu => "iGPU",
            DeviceClass::DGpu => "GPU",
        }
    }

    /// Devices sharing main memory with the host (the paper's CPU + iGPU
    /// on the Kaveri APU) can elide bulk copies under the *buffers*
    /// optimization.
    pub fn shares_host_memory(&self) -> bool {
        !matches!(self, DeviceClass::DGpu)
    }
}

/// Static description of one device visible to the engine.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    pub class: DeviceClass,
    /// Relative computing power estimate handed to the schedulers (the
    /// paper's `P_i`).  Normalized against the dGPU = 1.0.
    pub power: f64,
}

/// Execution mode of a run (paper §V-B / Fig. 6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Whole program: initialization + ROI + release.
    Binary,
    /// Region of interest only: transfers + kernel compute.
    Roi,
}

/// The two runtime optimizations proposed in paper §III.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Optimizations {
    /// Overlap platform/device discovery with Scheduler/Device thread
    /// preparation and reuse discovery structures.
    pub init_overlap: bool,
    /// Set buffer placement flags so same-main-memory devices map instead
    /// of copying.
    pub buffer_flags: bool,
}

impl Optimizations {
    pub const NONE: Self = Self { init_overlap: false, buffer_flags: false };
    pub const INIT: Self = Self { init_overlap: true, buffer_flags: false };
    pub const ALL: Self = Self { init_overlap: true, buffer_flags: true };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_range_expands_to_items() {
        let g = GroupRange::new(2, 5);
        assert_eq!(g.len(), 3);
        let items = g.items(128);
        assert_eq!(items.begin, 256);
        assert_eq!(items.end, 640);
        assert_eq!(items.len(), 384);
    }

    #[test]
    fn empty_ranges() {
        assert!(GroupRange::new(7, 7).is_empty());
        assert!(ItemRange::new(0, 0).is_empty());
        assert!(!GroupRange::new(0, 1).is_empty());
    }

    #[test]
    fn device_class_memory_sharing_matches_paper_testbed() {
        assert!(DeviceClass::Cpu.shares_host_memory());
        assert!(DeviceClass::IGpu.shares_host_memory());
        assert!(!DeviceClass::DGpu.shares_host_memory());
    }
}

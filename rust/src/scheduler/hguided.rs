//! HGuided scheduler (paper §II-B + §V-B): guided-style decay sized by
//! per-device computing power, with per-device minimum package sizes.
//!
//! On each request from device `i`:
//! ```text
//! packet_size_i = max( m_i ,  ceil( G_r * P_i / (k_i * n * Σ_j P_j) ) )
//! ```
//! in work-groups, where `G_r` is the pending work-group count (updated on
//! every launch), `k_i` the decay constant and `m_i` the minimum package
//! size expressed as a multiplier of the local work size (1 group = 1 lws).
//!
//! The paper's tuning (§V-B, Fig. 5): larger minimum sizes and smaller k
//! for more powerful devices; best combination m = {1, 15, 30},
//! k = {3.5, 1.5, 1} for {CPU, iGPU, GPU}; best single k = 2.

use super::{SchedCtx, Scheduler};
use crate::types::{DeviceId, GroupRange};


/// Per-device (m, k) pairs.
#[derive(Debug, Clone, PartialEq)]
pub struct HGuidedParams {
    /// Minimum package size per device, in work-groups (multiplier of lws).
    pub min_mult: Vec<u64>,
    /// Decay constant per device; k ∈ [1, 4] per the paper ("neither too
    /// large nor too small packages").
    pub k: Vec<f64>,
}

impl HGuidedParams {
    /// Uniform parameters for an n-device system.
    pub fn uniform(n: usize, m: u64, k: f64) -> Self {
        Self { min_mult: vec![m; n], k: vec![k; n] }
    }

    /// The pre-optimization default: m = 1, k = 2 for every device
    /// (k = 2 is the paper's best single-k choice).
    pub fn default_paper() -> Self {
        Self::uniform(3, 1, 2.0)
    }

    /// The paper's tuned configuration for {CPU, iGPU, GPU}:
    /// m = {1, 15, 30}, k = {3.5, 1.5, 1}.
    pub fn optimized_paper() -> Self {
        Self { min_mult: vec![1, 15, 30], k: vec![3.5, 1.5, 1.0] }
    }

    pub fn validate(&self, n_devices: usize) -> crate::Result<()> {
        use anyhow::ensure;
        ensure!(self.min_mult.len() == n_devices, "min_mult length mismatch");
        ensure!(self.k.len() == n_devices, "k length mismatch");
        ensure!(self.min_mult.iter().all(|&m| m >= 1), "m must be >= 1");
        ensure!(self.k.iter().all(|&k| k > 0.0), "k must be positive");
        Ok(())
    }
}

impl std::fmt::Display for HGuidedParams {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let m: Vec<String> = self.min_mult.iter().map(|m| m.to_string()).collect();
        let k: Vec<String> = self.k.iter().map(|k| format!("{k}")).collect();
        write!(f, "m{{{}}} k{{{}}}", m.join(","), k.join(","))
    }
}

pub struct HGuided {
    pending_begin: u64,
    total: u64,
    powers: Vec<f64>,
    power_sum: f64,
    params: HGuidedParams,
}

impl HGuided {
    pub fn new(ctx: &SchedCtx, params: HGuidedParams) -> Self {
        params
            .validate(ctx.n_devices())
            .expect("invalid HGuided parameters for this device count");
        Self {
            pending_begin: 0,
            total: ctx.total_groups,
            powers: ctx.powers.clone(),
            power_sum: ctx.power_sum(),
            params,
        }
    }

    /// Pending work-groups `G_r`.
    pub fn pending(&self) -> u64 {
        self.total - self.pending_begin
    }

    /// The paper's packet size formula for device `dev` at the current
    /// `G_r` (before clamping to the remaining work).
    pub fn packet_size(&self, dev: DeviceId) -> u64 {
        let gr = self.pending() as f64;
        let n = self.powers.len() as f64;
        let decayed =
            (gr * self.powers[dev] / (self.params.k[dev] * n * self.power_sum)).ceil() as u64;
        decayed.max(self.params.min_mult[dev]).max(1)
    }

    /// Grant `size` work-groups (clamped to the pending range) from the
    /// front of the index space; `None` once the workspace is drained.
    /// Shared by [`Scheduler::next`] and the deadline-aware wrapper
    /// (`scheduler::adaptive`), which caps `size` before granting.
    pub fn take(&mut self, size: u64) -> Option<GroupRange> {
        if self.pending_begin >= self.total {
            return None;
        }
        let size = size.max(1).min(self.pending());
        let begin = self.pending_begin;
        self.pending_begin += size;
        Some(GroupRange::new(begin, begin + size))
    }
}

impl Scheduler for HGuided {
    fn next(&mut self, dev: DeviceId) -> Option<GroupRange> {
        let size = self.packet_size(dev);
        self.take(size)
    }

    fn n_devices(&self) -> usize {
        self.powers.len()
    }

    fn label(&self) -> String {
        format!("HGuided {}", self.params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> SchedCtx {
        SchedCtx::new(10_000, vec![0.15, 0.4, 1.0])
    }

    #[test]
    fn packet_sizes_decay_monotonically_per_device() {
        let mut h = HGuided::new(&ctx(), HGuidedParams::default_paper());
        let mut last = u64::MAX;
        for _ in 0..50 {
            match h.next(2) {
                Some(g) => {
                    assert!(g.len() <= last, "grew: {} > {last}", g.len());
                    last = g.len();
                }
                None => break,
            }
        }
    }

    #[test]
    fn first_packet_matches_formula() {
        let h = HGuided::new(&ctx(), HGuidedParams::default_paper());
        // ceil(10000 * 1.0 / (2 * 3 * 1.55)) = ceil(1075.27) = 1076
        assert_eq!(h.packet_size(2), 1076);
        // CPU: ceil(10000 * 0.15 / 9.3) = ceil(161.29) = 162
        assert_eq!(h.packet_size(0), 162);
    }

    #[test]
    fn min_package_floor_applies() {
        let params = HGuidedParams::optimized_paper();
        let ctx = SchedCtx::new(100, vec![0.15, 0.4, 1.0]);
        let h = HGuided::new(&ctx, params);
        // GPU decay term: ceil(100 / (1 * 3 * 1.55) * 1.0) = 22, but m=30.
        assert_eq!(h.packet_size(2), 30);
    }

    #[test]
    fn smaller_k_gives_larger_packets() {
        let h1 = HGuided::new(&ctx(), HGuidedParams::uniform(3, 1, 1.0));
        let h4 = HGuided::new(&ctx(), HGuidedParams::uniform(3, 1, 4.0));
        assert!(h1.packet_size(2) > h4.packet_size(2));
    }

    #[test]
    fn more_powerful_devices_get_bigger_packets() {
        let h = HGuided::new(&ctx(), HGuidedParams::default_paper());
        assert!(h.packet_size(2) > h.packet_size(1));
        assert!(h.packet_size(1) > h.packet_size(0));
    }

    #[test]
    fn last_packet_clamps_to_remaining() {
        let ctx = SchedCtx::new(10, vec![1.0, 1.0, 1.0]);
        let mut h = HGuided::new(&ctx, HGuidedParams::uniform(3, 8, 1.0));
        let g1 = h.next(0).unwrap();
        assert_eq!(g1.len(), 8); // min floor
        let g2 = h.next(1).unwrap();
        assert_eq!(g2.len(), 2, "clamped to remaining");
        assert!(h.next(2).is_none());
    }

    #[test]
    fn gr_updates_with_every_launch() {
        let mut h = HGuided::new(&ctx(), HGuidedParams::default_paper());
        let before = h.pending();
        let g = h.next(2).unwrap();
        assert_eq!(h.pending(), before - g.len());
    }

    #[test]
    fn display_roundtrip_labels() {
        let p = HGuidedParams::optimized_paper();
        assert_eq!(format!("{p}"), "m{1,15,30} k{3.5,1.5,1}");
    }

    #[test]
    #[should_panic(expected = "invalid HGuided parameters")]
    fn wrong_arity_panics() {
        let ctx = SchedCtx::new(10, vec![1.0, 1.0]);
        HGuided::new(&ctx, HGuidedParams::optimized_paper()); // 3 params, 2 devs
    }
}

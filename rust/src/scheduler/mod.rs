//! Pluggable load-balancing schedulers (paper §II-B).
//!
//! All schedulers are *pull-based*: an idle device asks for its next
//! package and receives a contiguous [`GroupRange`] (work-groups, the
//! paper's granularity — `G_r` is the pending work-group count).  The same
//! scheduler objects drive both the virtual-clock simulator and the
//! threaded PJRT backend; in the latter they sit behind a mutex owned by
//! the host thread, which is exactly the serialization the paper's
//! "Runtime and Scheduler are CPU-managed" remark describes.

pub mod dynamic;
pub mod hguided;
pub mod r#static;

pub use dynamic::Dynamic;
pub use hguided::{HGuided, HGuidedParams};
pub use r#static::Static;

use crate::types::{DeviceId, GroupRange};


/// Immutable context a scheduler is built against.
#[derive(Debug, Clone)]
pub struct SchedCtx {
    /// Total work-groups in the launch.
    pub total_groups: u64,
    /// Scheduler's computing-power estimates `P_i`, one per device.
    pub powers: Vec<f64>,
}

impl SchedCtx {
    pub fn new(total_groups: u64, powers: Vec<f64>) -> Self {
        assert!(!powers.is_empty(), "scheduler needs at least one device");
        assert!(powers.iter().all(|&p| p > 0.0), "powers must be positive");
        Self { total_groups, powers }
    }

    pub fn n_devices(&self) -> usize {
        self.powers.len()
    }

    pub fn power_sum(&self) -> f64 {
        self.powers.iter().sum()
    }
}

/// A load-balancing strategy instance (one per run; stateful).
pub trait Scheduler: Send {
    /// Next package for an idle device; `None` = nothing left for it.
    fn next(&mut self, dev: DeviceId) -> Option<GroupRange>;

    /// Initial delivery order of devices (paper: Static hands the first
    /// chunk to the CPU, Static-rev to the GPU).  Devices become idle in
    /// this order at t=0.
    fn delivery_order(&self) -> Vec<DeviceId> {
        (0..self.n_devices()).collect()
    }

    fn n_devices(&self) -> usize;

    /// Human-readable configuration label (figure legends).
    fn label(&self) -> String;
}

/// Scheduler configuration — the seven bars of Fig. 3 plus free params.
#[derive(Debug, Clone, PartialEq)]
pub enum SchedulerKind {
    /// Power-proportional one-shot split, CPU-first delivery.
    Static,
    /// Same split, GPU-first delivery (paper "Static rev").
    StaticRev,
    /// Equal chunks, `n_chunks` total.
    Dynamic { n_chunks: u64 },
    /// HGuided with per-device (m, k) parameter pairs.
    HGuided { params: HGuidedParams },
}

impl SchedulerKind {
    /// The paper's seven Fig.-3 configurations, in bar order.
    pub fn fig3_configs() -> Vec<SchedulerKind> {
        vec![
            SchedulerKind::Static,
            SchedulerKind::StaticRev,
            SchedulerKind::Dynamic { n_chunks: 64 },
            SchedulerKind::Dynamic { n_chunks: 128 },
            SchedulerKind::Dynamic { n_chunks: 512 },
            SchedulerKind::HGuided { params: HGuidedParams::default_paper() },
            SchedulerKind::HGuided { params: HGuidedParams::optimized_paper() },
        ]
    }

    /// Instantiate a fresh scheduler for one run.
    pub fn build(&self, ctx: &SchedCtx) -> Box<dyn Scheduler> {
        match self {
            SchedulerKind::Static => Box::new(Static::new(ctx, false)),
            SchedulerKind::StaticRev => Box::new(Static::new(ctx, true)),
            SchedulerKind::Dynamic { n_chunks } => Box::new(Dynamic::new(ctx, *n_chunks)),
            SchedulerKind::HGuided { params } => Box::new(HGuided::new(ctx, params.clone())),
        }
    }

    pub fn label(&self) -> String {
        match self {
            SchedulerKind::Static => "Static".into(),
            SchedulerKind::StaticRev => "Static rev".into(),
            SchedulerKind::Dynamic { n_chunks } => format!("Dyn {n_chunks}"),
            SchedulerKind::HGuided { params } => {
                if *params == HGuidedParams::optimized_paper() {
                    "HGuided opt".into()
                } else if *params == HGuidedParams::default_paper() {
                    "HGuided".into()
                } else {
                    format!("HGuided {params}")
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drain a scheduler round-robin and assert full disjoint coverage.
    pub(crate) fn drain_and_check_coverage(
        mut s: Box<dyn Scheduler>,
        total: u64,
    ) -> Vec<(DeviceId, GroupRange)> {
        let n = s.n_devices();
        let mut granted: Vec<(DeviceId, GroupRange)> = Vec::new();
        let mut live: Vec<DeviceId> = s.delivery_order();
        assert_eq!(live.len(), n);
        while !live.is_empty() {
            let mut next_live = Vec::new();
            for &d in &live {
                match s.next(d) {
                    Some(g) => {
                        assert!(!g.is_empty(), "empty grant to {d}");
                        granted.push((d, g));
                        next_live.push(d);
                    }
                    None => {}
                }
            }
            live = next_live;
        }
        // Coverage: sorted ranges tile [0, total) exactly.
        let mut ranges: Vec<GroupRange> = granted.iter().map(|&(_, g)| g).collect();
        ranges.sort_by_key(|g| g.begin);
        let mut cursor = 0;
        for g in &ranges {
            assert_eq!(g.begin, cursor, "gap or overlap at group {cursor}");
            cursor = g.end;
        }
        assert_eq!(cursor, total, "work not fully covered");
        granted
    }

    #[test]
    fn fig3_has_seven_bars() {
        let cfgs = SchedulerKind::fig3_configs();
        assert_eq!(cfgs.len(), 7);
        assert_eq!(cfgs[0].label(), "Static");
        assert_eq!(cfgs[6].label(), "HGuided opt");
    }

    #[test]
    fn all_kinds_cover_workspace() {
        let ctx = SchedCtx::new(1000, vec![0.15, 0.4, 1.0]);
        for kind in SchedulerKind::fig3_configs() {
            drain_and_check_coverage(kind.build(&ctx), 1000);
        }
    }

    #[test]
    fn coverage_holds_for_tiny_workloads() {
        // Fewer groups than devices/chunks: no scheduler may lose work.
        for total in [1u64, 2, 3, 5] {
            let ctx = SchedCtx::new(total, vec![0.15, 0.4, 1.0]);
            for kind in SchedulerKind::fig3_configs() {
                drain_and_check_coverage(kind.build(&ctx), total);
            }
        }
    }

    #[test]
    #[should_panic(expected = "powers must be positive")]
    fn zero_power_rejected() {
        SchedCtx::new(10, vec![0.0, 1.0]);
    }
}

//! Pluggable load-balancing schedulers (paper §II-B).
//!
//! All schedulers are *pull-based*: an idle device asks for its next
//! package and receives a contiguous [`GroupRange`] (work-groups, the
//! paper's granularity — `G_r` is the pending work-group count).  The same
//! scheduler objects drive both the virtual-clock simulator and the
//! threaded PJRT backend; in the latter they sit behind a mutex owned by
//! the host thread, which is exactly the serialization the paper's
//! "Runtime and Scheduler are CPU-managed" remark describes.

pub mod adaptive;
pub mod dynamic;
pub mod hguided;
pub mod r#static;

pub use adaptive::{Adaptive, AdaptiveParams};
pub use dynamic::Dynamic;
pub use hguided::{HGuided, HGuidedParams};
pub use r#static::Static;

use crate::types::{DeviceId, GroupRange};


/// Immutable context a scheduler is built against.  For pipeline stages
/// running on a masked device subset this is a **sub-pool** context:
/// device slots are stage-local, and [`SchedCtx::pool_ids`] maps each
/// slot back to its pool-wide device id.
#[derive(Debug, Clone)]
pub struct SchedCtx {
    /// Total work-groups in the launch.
    pub total_groups: u64,
    /// Scheduler's computing-power estimates `P_i`, one per device.
    pub powers: Vec<f64>,
    /// ROI deadline for time-constrained runs (seconds, ROI-relative);
    /// `None` = unconstrained.
    pub deadline_s: Option<f64>,
    /// Estimated per-device throughput in work-groups/second, derived from
    /// the same `P_i` estimates — the basis for deadline-aware package
    /// caps.  `None` = no hint available.
    pub groups_per_sec: Option<Vec<f64>>,
    /// Pool device id backing each scheduler-local slot (identity for
    /// full-pool runs).
    pub pool_ids: Vec<DeviceId>,
}

impl SchedCtx {
    pub fn new(total_groups: u64, powers: Vec<f64>) -> Self {
        assert!(!powers.is_empty(), "scheduler needs at least one device");
        assert!(powers.iter().all(|&p| p > 0.0), "powers must be positive");
        let pool_ids = (0..powers.len()).collect();
        Self { total_groups, powers, deadline_s: None, groups_per_sec: None, pool_ids }
    }

    /// Attach a time-constrained scenario: ROI deadline plus the estimated
    /// device throughputs the deadline-aware schedulers size against.
    pub fn with_deadline(mut self, deadline_s: f64, groups_per_sec: Vec<f64>) -> Self {
        assert!(deadline_s > 0.0, "deadline must be positive");
        assert_eq!(groups_per_sec.len(), self.powers.len(), "throughput arity mismatch");
        self.deadline_s = Some(deadline_s);
        self.groups_per_sec = Some(groups_per_sec);
        self
    }

    /// Mark this context as a sub-pool view: `pool_ids[slot]` is the pool
    /// device id behind scheduler-local slot `slot`.
    pub fn with_pool_ids(mut self, pool_ids: Vec<DeviceId>) -> Self {
        assert_eq!(pool_ids.len(), self.powers.len(), "pool id arity mismatch");
        self.pool_ids = pool_ids;
        self
    }

    pub fn n_devices(&self) -> usize {
        self.powers.len()
    }

    pub fn power_sum(&self) -> f64 {
        self.powers.iter().sum()
    }
}

/// A load-balancing strategy instance (one per run; stateful).
pub trait Scheduler: Send {
    /// Next package for an idle device; `None` = nothing left for it.
    fn next(&mut self, dev: DeviceId) -> Option<GroupRange>;

    /// Clock tick from the backend: `now_s` is the ROI-relative time of
    /// the upcoming grant.  Time-aware schedulers (deadline scenarios)
    /// adapt their sizing; the default scheduler is stateless in time.
    fn on_clock(&mut self, _now_s: f64) {}

    /// Initial delivery order of devices (paper: Static hands the first
    /// chunk to the CPU, Static-rev to the GPU).  Devices become idle in
    /// this order at t=0.
    fn delivery_order(&self) -> Vec<DeviceId> {
        (0..self.n_devices()).collect()
    }

    fn n_devices(&self) -> usize;

    /// Human-readable configuration label (figure legends).
    fn label(&self) -> String;
}

/// Scheduler configuration — the seven bars of Fig. 3 plus free params.
#[derive(Debug, Clone, PartialEq)]
pub enum SchedulerKind {
    /// Power-proportional one-shot split, CPU-first delivery.
    Static,
    /// Same split, GPU-first delivery (paper "Static rev").
    StaticRev,
    /// Equal chunks, `n_chunks` total.
    Dynamic { n_chunks: u64 },
    /// HGuided with per-device (m, k) parameter pairs.
    HGuided { params: HGuidedParams },
    /// Deadline-aware HGuided derivative (paper's time-constrained
    /// improvement): pessimistic completion caps + shrinking floors.
    Adaptive { params: AdaptiveParams },
}

impl SchedulerKind {
    /// The paper's seven Fig.-3 configurations, in bar order.
    pub fn fig3_configs() -> Vec<SchedulerKind> {
        vec![
            SchedulerKind::Static,
            SchedulerKind::StaticRev,
            SchedulerKind::Dynamic { n_chunks: 64 },
            SchedulerKind::Dynamic { n_chunks: 128 },
            SchedulerKind::Dynamic { n_chunks: 512 },
            SchedulerKind::HGuided { params: HGuidedParams::default_paper() },
            SchedulerKind::HGuided { params: HGuidedParams::optimized_paper() },
        ]
    }

    /// The Fig.-3 configurations plus the deadline-aware Adaptive
    /// scheduler — the bar set of the deadline sweep.
    pub fn all_configs() -> Vec<SchedulerKind> {
        let mut v = Self::fig3_configs();
        v.push(SchedulerKind::Adaptive { params: AdaptiveParams::default_paper() });
        v
    }

    /// The same configuration under a pipeline energy policy: the policy
    /// modulates the Adaptive completion-cap pessimism (race-to-idle keeps
    /// the configured guard, stretch-to-deadline raises it); every
    /// deadline-blind scheduler is returned unchanged.
    pub fn for_energy_policy(&self, policy: crate::types::EnergyPolicy) -> SchedulerKind {
        match self {
            SchedulerKind::Adaptive { params } => SchedulerKind::Adaptive {
                params: params.clone().with_pessimism(policy.pessimism(params.pessimism)),
            },
            other => other.clone(),
        }
    }

    /// The same configuration restricted to a device-pool subset: the
    /// per-device parameter vectors (HGuided/Adaptive `m_i`, `k_i`) are
    /// remapped by pool id so a GPU-only stage keeps the GPU's tuning
    /// rather than inheriting the CPU's.  Parameter vectors that don't
    /// cover the pool (already view-local, or custom arities) are kept
    /// unchanged; parameter-free schedulers pass through.
    pub fn for_device_subset(&self, pool_ids: &[crate::types::DeviceId]) -> SchedulerKind {
        fn subset<T: Copy>(v: &[T], pool_ids: &[usize]) -> Option<Vec<T>> {
            pool_ids.iter().map(|&i| v.get(i).copied()).collect()
        }
        match self {
            SchedulerKind::HGuided { params } => {
                match (subset(&params.min_mult, pool_ids), subset(&params.k, pool_ids)) {
                    (Some(min_mult), Some(k)) => {
                        SchedulerKind::HGuided { params: HGuidedParams { min_mult, k } }
                    }
                    _ => self.clone(),
                }
            }
            SchedulerKind::Adaptive { params } => {
                match (subset(&params.min_mult, pool_ids), subset(&params.k, pool_ids)) {
                    (Some(min_mult), Some(k)) => SchedulerKind::Adaptive {
                        params: AdaptiveParams { min_mult, k, pessimism: params.pessimism },
                    },
                    _ => self.clone(),
                }
            }
            other => other.clone(),
        }
    }

    /// Instantiate a fresh scheduler for one run.  Sub-pool contexts
    /// ([`SchedCtx::pool_ids`]) remap per-device parameters by pool id
    /// via [`SchedulerKind::for_device_subset`]; the identity mapping is
    /// a no-op.
    pub fn build(&self, ctx: &SchedCtx) -> Box<dyn Scheduler> {
        match self.for_device_subset(&ctx.pool_ids) {
            SchedulerKind::Static => Box::new(Static::new(ctx, false)),
            SchedulerKind::StaticRev => Box::new(Static::new(ctx, true)),
            SchedulerKind::Dynamic { n_chunks } => Box::new(Dynamic::new(ctx, n_chunks)),
            SchedulerKind::HGuided { params } => Box::new(HGuided::new(ctx, params)),
            SchedulerKind::Adaptive { params } => Box::new(Adaptive::new(ctx, params)),
        }
    }

    pub fn label(&self) -> String {
        match self {
            SchedulerKind::Static => "Static".into(),
            SchedulerKind::StaticRev => "Static rev".into(),
            SchedulerKind::Dynamic { n_chunks } => format!("Dyn {n_chunks}"),
            SchedulerKind::HGuided { params } => {
                if *params == HGuidedParams::optimized_paper() {
                    "HGuided opt".into()
                } else if *params == HGuidedParams::default_paper() {
                    "HGuided".into()
                } else {
                    format!("HGuided {params}")
                }
            }
            SchedulerKind::Adaptive { .. } => "Adaptive".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drain a scheduler round-robin and assert full disjoint coverage.
    pub(crate) fn drain_and_check_coverage(
        mut s: Box<dyn Scheduler>,
        total: u64,
    ) -> Vec<(DeviceId, GroupRange)> {
        let n = s.n_devices();
        let mut granted: Vec<(DeviceId, GroupRange)> = Vec::new();
        let mut live: Vec<DeviceId> = s.delivery_order();
        assert_eq!(live.len(), n);
        while !live.is_empty() {
            let mut next_live = Vec::new();
            for &d in &live {
                if let Some(g) = s.next(d) {
                    assert!(!g.is_empty(), "empty grant to {d}");
                    granted.push((d, g));
                    next_live.push(d);
                }
            }
            live = next_live;
        }
        // Coverage: sorted ranges tile [0, total) exactly.
        let mut ranges: Vec<GroupRange> = granted.iter().map(|&(_, g)| g).collect();
        ranges.sort_by_key(|g| g.begin);
        let mut cursor = 0;
        for g in &ranges {
            assert_eq!(g.begin, cursor, "gap or overlap at group {cursor}");
            cursor = g.end;
        }
        assert_eq!(cursor, total, "work not fully covered");
        granted
    }

    #[test]
    fn fig3_has_seven_bars() {
        let cfgs = SchedulerKind::fig3_configs();
        assert_eq!(cfgs.len(), 7);
        assert_eq!(cfgs[0].label(), "Static");
        assert_eq!(cfgs[6].label(), "HGuided opt");
    }

    #[test]
    fn all_configs_append_adaptive() {
        let cfgs = SchedulerKind::all_configs();
        assert_eq!(cfgs.len(), 8);
        assert_eq!(cfgs[7].label(), "Adaptive");
    }

    #[test]
    fn all_kinds_cover_workspace() {
        let ctx = SchedCtx::new(1000, vec![0.15, 0.4, 1.0]);
        for kind in SchedulerKind::all_configs() {
            drain_and_check_coverage(kind.build(&ctx), 1000);
        }
    }

    #[test]
    fn coverage_holds_for_tiny_workloads() {
        // Fewer groups than devices/chunks: no scheduler may lose work.
        for total in [1u64, 2, 3, 5] {
            let ctx = SchedCtx::new(total, vec![0.15, 0.4, 1.0]);
            for kind in SchedulerKind::all_configs() {
                drain_and_check_coverage(kind.build(&ctx), total);
            }
        }
    }

    #[test]
    fn coverage_holds_under_deadline_contexts() {
        // Deadline + throughput hints must not break coverage for any
        // scheduler (the deadline-blind ones simply ignore them).
        for deadline in [1e-4, 0.5, 1e6] {
            let ctx = SchedCtx::new(997, vec![0.15, 0.4, 1.0])
                .with_deadline(deadline, vec![50.0, 130.0, 330.0]);
            for kind in SchedulerKind::all_configs() {
                drain_and_check_coverage(kind.build(&ctx), 997);
            }
        }
    }

    #[test]
    fn on_clock_default_is_noop_for_legacy_schedulers() {
        let ctx = SchedCtx::new(100, vec![0.15, 0.4, 1.0]);
        for kind in SchedulerKind::fig3_configs() {
            let mut s = kind.build(&ctx);
            s.on_clock(123.0);
            assert!(s.next(0).is_some(), "{}: grant survives clock tick", kind.label());
        }
    }

    #[test]
    #[should_panic(expected = "powers must be positive")]
    fn zero_power_rejected() {
        SchedCtx::new(10, vec![0.0, 1.0]);
    }

    #[test]
    fn pool_ids_default_to_identity() {
        let ctx = SchedCtx::new(10, vec![0.5, 1.0]);
        assert_eq!(ctx.pool_ids, vec![0, 1]);
        let sub = SchedCtx::new(10, vec![1.0]).with_pool_ids(vec![2]);
        assert_eq!(sub.pool_ids, vec![2]);
    }

    #[test]
    #[should_panic(expected = "pool id arity mismatch")]
    fn pool_ids_arity_checked() {
        SchedCtx::new(10, vec![0.5, 1.0]).with_pool_ids(vec![0]);
    }

    #[test]
    fn device_subset_remaps_per_device_params() {
        // A GPU-only view keeps the GPU's tuned (m, k), not the CPU's.
        let opt = SchedulerKind::HGuided { params: HGuidedParams::optimized_paper() };
        match opt.for_device_subset(&[2]) {
            SchedulerKind::HGuided { params } => {
                assert_eq!(params.min_mult, vec![30]);
                assert_eq!(params.k, vec![1.0]);
            }
            other => panic!("kind changed: {other:?}"),
        }
        let ad = SchedulerKind::Adaptive { params: AdaptiveParams::default_paper() };
        match ad.for_device_subset(&[0, 2]) {
            SchedulerKind::Adaptive { params } => {
                assert_eq!(params.min_mult, vec![1, 30]);
                assert_eq!(params.k, vec![3.5, 1.0]);
                assert_eq!(params.pessimism, 0.25);
            }
            other => panic!("kind changed: {other:?}"),
        }
        // Identity subset is a no-op; parameter-free kinds pass through.
        assert_eq!(opt.for_device_subset(&[0, 1, 2]), opt);
        assert_eq!(SchedulerKind::Static.for_device_subset(&[2]), SchedulerKind::Static);
        // Already-view-local params (arity 1) can't cover pool id 2: kept.
        let local = SchedulerKind::HGuided { params: HGuidedParams::uniform(1, 5, 2.0) };
        assert_eq!(local.for_device_subset(&[2]), local);
        // `build` applies the remap itself from the sub-pool context, so
        // full-arity configurations build directly against masked views.
        let ctx = SchedCtx::new(100, vec![1.0]).with_pool_ids(vec![2]);
        let mut built = opt.build(&ctx);
        assert_eq!(built.n_devices(), 1);
        let mut cursor = 0;
        while let Some(g) = built.next(0) {
            assert_eq!(g.begin, cursor);
            cursor = g.end;
        }
        assert_eq!(cursor, 100, "sub-pool build covers the workspace");
    }

    #[test]
    fn energy_policy_modulates_only_adaptive() {
        use crate::types::EnergyPolicy;
        let adaptive = SchedulerKind::Adaptive { params: AdaptiveParams::default_paper() };
        let raced = adaptive.for_energy_policy(EnergyPolicy::RaceToIdle);
        assert_eq!(raced, adaptive, "racing keeps the configured guard");
        let stretched = adaptive.for_energy_policy(EnergyPolicy::StretchToDeadline);
        match &stretched {
            SchedulerKind::Adaptive { params } => {
                assert_eq!(params.pessimism, 0.55);
                assert_eq!(params.min_mult, AdaptiveParams::default_paper().min_mult);
            }
            other => panic!("adaptive stayed adaptive, got {other:?}"),
        }
        for kind in SchedulerKind::fig3_configs() {
            assert_eq!(kind.for_energy_policy(EnergyPolicy::StretchToDeadline), kind);
        }
    }
}

//! Dynamic scheduler: the global index space is cut into `n_chunks` equal
//! packages and idle devices pull them FIFO (paper §II-B).  Adaptive but
//! power-blind — the paper's Fig. 3 shows it losing to Static on regular
//! kernels (synchronization overhead) and winning on irregular ones.

use super::{SchedCtx, Scheduler};
use crate::types::{DeviceId, GroupRange};

pub struct Dynamic {
    total: u64,
    chunk: u64,
    cursor: u64,
    n_devices: usize,
    n_chunks: u64,
}

impl Dynamic {
    pub fn new(ctx: &SchedCtx, n_chunks: u64) -> Self {
        assert!(n_chunks > 0, "Dynamic needs at least one chunk");
        let chunk = ctx.total_groups.div_ceil(n_chunks).max(1);
        Self {
            total: ctx.total_groups,
            chunk,
            cursor: 0,
            n_devices: ctx.n_devices(),
            n_chunks,
        }
    }

    /// Remaining work-groups (the paper's `G_r`).
    pub fn pending(&self) -> u64 {
        self.total - self.cursor
    }
}

impl Scheduler for Dynamic {
    fn next(&mut self, _dev: DeviceId) -> Option<GroupRange> {
        if self.cursor >= self.total {
            return None;
        }
        let begin = self.cursor;
        let end = (begin + self.chunk).min(self.total);
        self.cursor = end;
        Some(GroupRange::new(begin, end))
    }

    fn n_devices(&self) -> usize {
        self.n_devices
    }

    fn label(&self) -> String {
        format!("Dyn {}", self.n_chunks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_chunks_except_tail() {
        let ctx = SchedCtx::new(1000, vec![1.0, 1.0]);
        let mut d = Dynamic::new(&ctx, 64);
        let mut sizes = Vec::new();
        while let Some(g) = d.next(0) {
            sizes.push(g.len());
        }
        assert_eq!(sizes.iter().sum::<u64>(), 1000);
        // ceil(1000/64) = 16 -> 62 chunks of 16 + tail 8
        assert!(sizes[..sizes.len() - 1].iter().all(|&s| s == 16));
        assert_eq!(*sizes.last().unwrap(), 8);
    }

    #[test]
    fn chunk_count_close_to_requested() {
        let ctx = SchedCtx::new(10_000, vec![1.0, 1.0, 1.0]);
        let mut d = Dynamic::new(&ctx, 128);
        let mut n = 0;
        while d.next(2).is_some() {
            n += 1;
        }
        assert!(n <= 128 && n >= 126, "{n} chunks");
    }

    #[test]
    fn device_agnostic_fifo() {
        let ctx = SchedCtx::new(100, vec![1.0, 1.0]);
        let mut d = Dynamic::new(&ctx, 10);
        let a = d.next(0).unwrap();
        let b = d.next(1).unwrap();
        assert_eq!(a.end, b.begin, "contiguous FIFO handout");
    }

    #[test]
    fn more_chunks_than_groups_degrades_to_singletons() {
        let ctx = SchedCtx::new(5, vec![1.0]);
        let mut d = Dynamic::new(&ctx, 512);
        let mut n = 0;
        while let Some(g) = d.next(0) {
            assert_eq!(g.len(), 1);
            n += 1;
        }
        assert_eq!(n, 5);
    }

    #[test]
    fn pending_tracks_cursor() {
        let ctx = SchedCtx::new(100, vec![1.0]);
        let mut d = Dynamic::new(&ctx, 10);
        assert_eq!(d.pending(), 100);
        d.next(0);
        assert_eq!(d.pending(), 90);
    }
}

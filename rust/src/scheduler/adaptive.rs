//! Deadline-aware scheduler — the paper's improved load-balancing
//! algorithm for *time-constrained scenarios*.
//!
//! Builds on HGuided's power-proportional decay and adds a **pessimistic
//! completion cap**: a device asking for work at time `now` is never
//! handed more than `(1 - pessimism) · thr_i · (deadline - now)`
//! work-groups — under pessimistic power estimation no single grant can
//! push its device past the deadline.  The cap doubles as an **adaptive
//! minimum-package floor**: the effective floor is `min(m_i, cap_i)`, so
//! as the deadline approaches even the minimum package shrinks (down to a
//! single work-group) and the finish times cluster in front of the
//! deadline instead of straggling past it.  Once the deadline is lost the
//! cap disengages and the scheduler finishes in plain efficiency mode
//! instead of thrashing tiny packages.
//!
//! Without a deadline in the [`SchedCtx`] the grant sequence is
//! *identical* to HGuided's with the same `(m, k)` — `Adaptive` is a
//! strict superset of the paper's best Fig.-3 configuration.  (An earlier
//! design also shrank floors throughout the run and delivered to the
//! fastest device first; both measurably hurt — run-long shrink inflates
//! the package count, and fastest-first pushes the large PCIe upload to
//! the front of the serialized host thread, delaying every other device.)

use super::{HGuided, HGuidedParams, SchedCtx, Scheduler};
use crate::types::{DeviceId, GroupRange};

/// Parameters of the deadline-aware scheduler.
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptiveParams {
    /// Baseline minimum package sizes per device, in work-groups (the
    /// HGuided `m_i`); the effective floor is `min(m_i, cap_i)`.
    pub min_mult: Vec<u64>,
    /// Decay constants per device (the HGuided `k_i`).
    pub k: Vec<f64>,
    /// Throughput derating for the completion cap, in [0, 1): 0 trusts
    /// the power estimates, larger values guard harder against
    /// overcommitting a device close to the deadline.
    pub pessimism: f64,
}

impl AdaptiveParams {
    /// Default: the paper's tuned HGuided parameters with a 25 %
    /// pessimistic throughput guard.
    pub fn default_paper() -> Self {
        let h = HGuidedParams::optimized_paper();
        Self { min_mult: h.min_mult, k: h.k, pessimism: 0.25 }
    }

    /// Uniform parameters for an n-device system.
    pub fn uniform(n: usize, m: u64, k: f64, pessimism: f64) -> Self {
        Self { min_mult: vec![m; n], k: vec![k; n], pessimism }
    }

    /// Same parameters with the completion-cap pessimism replaced — how
    /// the pipeline engine's [`crate::types::EnergyPolicy`] modulates the
    /// scheduler without touching the HGuided sizing.
    pub fn with_pessimism(mut self, pessimism: f64) -> Self {
        self.pessimism = pessimism;
        self
    }

    /// The HGuided parameter subset (sizing is delegated wholesale).
    pub fn hguided(&self) -> HGuidedParams {
        HGuidedParams { min_mult: self.min_mult.clone(), k: self.k.clone() }
    }

    pub fn validate(&self, n_devices: usize) -> crate::Result<()> {
        use anyhow::ensure;
        self.hguided().validate(n_devices)?;
        ensure!(
            (0.0..1.0).contains(&self.pessimism),
            "pessimism must be in [0, 1), got {}",
            self.pessimism
        );
        Ok(())
    }
}

impl std::fmt::Display for AdaptiveParams {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let m: Vec<String> = self.min_mult.iter().map(|m| m.to_string()).collect();
        let k: Vec<String> = self.k.iter().map(|k| format!("{k}")).collect();
        write!(f, "m{{{}}} k{{{}}} p{}", m.join(","), k.join(","), self.pessimism)
    }
}

pub struct Adaptive {
    /// The HGuided core: decay formula, floors, and the grant cursor.
    /// Delegating (rather than duplicating the formula) is what makes
    /// the "identical to HGuided when unconstrained" invariant hold by
    /// construction.
    inner: HGuided,
    params: AdaptiveParams,
    /// ROI deadline (seconds), if this run is time-constrained.
    deadline_s: Option<f64>,
    /// Estimated device throughputs in work-groups/second (same `P_i`
    /// source as the powers), feeding the completion cap.
    groups_per_sec: Option<Vec<f64>>,
    /// Latest backend clock observed via [`Scheduler::on_clock`].
    now_s: f64,
}

impl Adaptive {
    pub fn new(ctx: &SchedCtx, params: AdaptiveParams) -> Self {
        params
            .validate(ctx.n_devices())
            .expect("invalid Adaptive parameters for this device count");
        if let Some(thr) = &ctx.groups_per_sec {
            assert_eq!(thr.len(), ctx.n_devices(), "throughput hint arity mismatch");
        }
        Self {
            inner: HGuided::new(ctx, params.hguided()),
            params,
            deadline_s: ctx.deadline_s,
            groups_per_sec: ctx.groups_per_sec.clone(),
            now_s: 0.0,
        }
    }

    /// Pending work-groups `G_r`.
    pub fn pending(&self) -> u64 {
        self.inner.pending()
    }

    /// Pessimistic completion cap for `dev` at the current clock: the
    /// most work-groups it could finish before the deadline at
    /// `(1 - pessimism)` of its estimated throughput.  `u64::MAX` when
    /// unconstrained, unhinted, or once the deadline is already lost
    /// (plain efficiency mode — no tiny-package thrashing).
    pub fn cap(&self, dev: DeviceId) -> u64 {
        let (Some(d), Some(thr)) = (self.deadline_s, self.groups_per_sec.as_ref()) else {
            return u64::MAX;
        };
        let remaining = d - self.now_s;
        if remaining <= 0.0 {
            return u64::MAX;
        }
        let t = thr[dev];
        if !(t.is_finite() && t > 0.0) {
            return u64::MAX;
        }
        let feasible = (1.0 - self.params.pessimism) * t * remaining;
        (feasible.floor() as u64).max(1)
    }

    /// The adaptive minimum-package floor: `m_i` while the budget is
    /// comfortable, shrinking with the completion cap as the deadline
    /// approaches.
    pub fn floor(&self, dev: DeviceId) -> u64 {
        self.params.min_mult[dev].max(1).min(self.cap(dev))
    }

    /// Packet size for `dev` at the current `G_r` and clock (before
    /// clamping to the remaining work): HGuided's size, bounded by the
    /// completion cap.
    pub fn packet_size(&self, dev: DeviceId) -> u64 {
        self.inner.packet_size(dev).min(self.cap(dev))
    }
}

impl Scheduler for Adaptive {
    fn next(&mut self, dev: DeviceId) -> Option<GroupRange> {
        let size = self.packet_size(dev);
        self.inner.take(size)
    }

    fn on_clock(&mut self, now_s: f64) {
        self.now_s = self.now_s.max(now_s);
    }

    fn n_devices(&self) -> usize {
        self.inner.n_devices()
    }

    fn label(&self) -> String {
        "Adaptive".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::HGuided;

    fn ctx() -> SchedCtx {
        SchedCtx::new(10_000, vec![0.15, 0.4, 1.0])
    }

    fn deadline_ctx(deadline_s: f64, thr: Vec<f64>) -> SchedCtx {
        ctx().with_deadline(deadline_s, thr)
    }

    #[test]
    fn matches_hguided_sizing_without_deadline() {
        let a = Adaptive::new(&ctx(), AdaptiveParams::default_paper());
        let h = HGuided::new(&ctx(), HGuidedParams::optimized_paper());
        for dev in 0..3 {
            assert_eq!(a.packet_size(dev), h.packet_size(dev), "dev {dev}");
        }
    }

    #[test]
    fn delivery_order_matches_hguided() {
        // The serialized host thread should enqueue cheap shared-memory
        // uploads first (an earlier fastest-first variant measurably put
        // the big PCIe upload in front of every other device).
        let a = Adaptive::new(&ctx(), AdaptiveParams::default_paper());
        assert_eq!(a.delivery_order(), vec![0, 1, 2]);
    }

    #[test]
    fn cap_bounds_grants_near_deadline() {
        let mut a = Adaptive::new(
            &deadline_ctx(1.0, vec![100.0, 100.0, 100.0]),
            AdaptiveParams::uniform(3, 1, 2.0, 0.5),
        );
        a.on_clock(0.5);
        // (1 - 0.5) * 100 groups/s * 0.5 s remaining = 25 groups.
        assert_eq!(a.cap(2), 25);
        let g = a.next(2).unwrap();
        assert!(g.len() <= 25, "grant {} exceeds the pessimistic cap", g.len());
    }

    #[test]
    fn floor_shrinks_as_deadline_approaches() {
        let mut a = Adaptive::new(
            &deadline_ctx(1.0, vec![100.0, 100.0, 100.0]),
            AdaptiveParams::default_paper(),
        );
        assert_eq!(a.floor(2), 30, "full floor while the budget is comfortable");
        a.on_clock(0.8);
        // cap = 0.75 * 100 * 0.2 = 15 < m_gpu = 30.
        assert_eq!(a.floor(2), 15);
        a.on_clock(0.999);
        assert_eq!(a.floor(2), 1, "floor collapses at the deadline");
        assert!(a.floor(0) >= 1, "floor never reaches zero");
    }

    #[test]
    fn lost_deadline_reverts_to_efficiency_mode() {
        let mut a = Adaptive::new(
            &deadline_ctx(1.0, vec![100.0, 100.0, 100.0]),
            AdaptiveParams::default_paper(),
        );
        a.on_clock(2.0); // past the deadline
        assert_eq!(a.cap(2), u64::MAX, "cap disengages");
        assert_eq!(a.floor(2), 30, "floor restored: no 1-group thrashing");
    }

    #[test]
    fn clock_is_monotonic() {
        let mut a = Adaptive::new(
            &deadline_ctx(1.0, vec![100.0; 3]),
            AdaptiveParams::default_paper(),
        );
        a.on_clock(0.8);
        let late_cap = a.cap(2);
        a.on_clock(0.2); // stale tick must not rewind the clock
        assert_eq!(a.cap(2), late_cap);
    }

    #[test]
    fn covers_workspace_under_tight_deadline() {
        // Even an infeasible budget must not lose or duplicate work.
        let mut a = Adaptive::new(
            &deadline_ctx(1e-3, vec![10.0, 10.0, 10.0]),
            AdaptiveParams::default_paper(),
        );
        let mut cursor = 0;
        let mut clock = 0.0;
        loop {
            let dev = (cursor % 3) as usize;
            a.on_clock(clock);
            match a.next(dev) {
                Some(g) => {
                    assert_eq!(g.begin, cursor, "gap/overlap");
                    cursor = g.end;
                    clock += 1e-4;
                }
                None => break,
            }
        }
        assert_eq!(cursor, 10_000, "workspace fully covered");
    }

    #[test]
    fn missing_throughput_hint_degrades_to_hguided() {
        let mut c = ctx();
        c.deadline_s = Some(1.0); // deadline without a throughput hint
        let mut a = Adaptive::new(&c, AdaptiveParams::default_paper());
        a.on_clock(0.5);
        assert_eq!(a.cap(2), u64::MAX);
        assert_eq!(a.floor(2), 30, "plain HGuided floor without a hint");
        let h = HGuided::new(&ctx(), HGuidedParams::optimized_paper());
        assert_eq!(a.packet_size(2), h.packet_size(2));
    }

    #[test]
    #[should_panic(expected = "invalid Adaptive parameters")]
    fn bad_pessimism_rejected() {
        Adaptive::new(&ctx(), AdaptiveParams::uniform(3, 1, 2.0, 1.0));
    }
}

//! Static scheduler: one package per device, sized proportionally to the
//! computing-power estimates `P_i` (paper §II-B).  The *delivery order*
//! (which device's package is enqueued first by the host thread) is the
//! only difference between the paper's "Static" (CPU, iGPU, GPU) and
//! "Static rev" (GPU, iGPU, CPU) bars.

use super::{SchedCtx, Scheduler};
use crate::types::{DeviceId, GroupRange};

pub struct Static {
    /// Precomputed single package per device (device-indexed).
    parts: Vec<Option<GroupRange>>,
    order: Vec<DeviceId>,
    rev: bool,
}

impl Static {
    /// `rev = false`: deliver in device order 0..n (paper: CPU first);
    /// `rev = true`: reverse order (GPU first).
    pub fn new(ctx: &SchedCtx, rev: bool) -> Self {
        let n = ctx.n_devices();
        let total = ctx.total_groups;
        let psum = ctx.power_sum();
        // Largest-remainder apportionment: proportional, sums exactly.
        let exact: Vec<f64> =
            ctx.powers.iter().map(|p| total as f64 * p / psum).collect();
        let mut sizes: Vec<u64> = exact.iter().map(|e| e.floor() as u64).collect();
        let mut left = total - sizes.iter().sum::<u64>();
        let mut rema: Vec<(usize, f64)> =
            exact.iter().enumerate().map(|(i, e)| (i, e - e.floor())).collect();
        rema.sort_by(|a, b| b.1.total_cmp(&a.1));
        let mut i = 0;
        while left > 0 {
            sizes[rema[i % n].0] += 1;
            left -= 1;
            i += 1;
        }
        // Contiguous slices in device order (CPU gets the front of the
        // index space, GPU the back — matching the paper's delivery text).
        let mut parts = Vec::with_capacity(n);
        let mut cursor = 0;
        for &sz in &sizes {
            let g = GroupRange::new(cursor, cursor + sz);
            parts.push((!g.is_empty()).then_some(g));
            cursor += sz;
        }
        let order: Vec<DeviceId> =
            if rev { (0..n).rev().collect() } else { (0..n).collect() };
        Self { parts, order, rev }
    }

    /// The precomputed partition (for tests/reporting).
    pub fn partition(&self) -> Vec<Option<GroupRange>> {
        self.parts.clone()
    }
}

impl Scheduler for Static {
    fn next(&mut self, dev: DeviceId) -> Option<GroupRange> {
        self.parts.get_mut(dev)?.take()
    }

    fn delivery_order(&self) -> Vec<DeviceId> {
        self.order.clone()
    }

    fn n_devices(&self) -> usize {
        self.parts.len()
    }

    fn label(&self) -> String {
        if self.rev { "Static rev".into() } else { "Static".into() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> SchedCtx {
        SchedCtx::new(1000, vec![0.15, 0.4, 1.0])
    }

    #[test]
    fn split_is_power_proportional() {
        let s = Static::new(&ctx(), false);
        let parts = s.partition();
        let sizes: Vec<u64> = parts.iter().map(|p| p.unwrap().len()).collect();
        // 1000 * [0.15, 0.4, 1.0] / 1.55 ≈ [96.8, 258, 645.2]
        assert_eq!(sizes.iter().sum::<u64>(), 1000);
        assert!((sizes[0] as i64 - 97).abs() <= 1, "{sizes:?}");
        assert!((sizes[1] as i64 - 258).abs() <= 1, "{sizes:?}");
        assert!((sizes[2] as i64 - 645).abs() <= 1, "{sizes:?}");
    }

    #[test]
    fn one_package_each_then_none() {
        let mut s = Static::new(&ctx(), false);
        for d in 0..3 {
            assert!(s.next(d).is_some());
            assert!(s.next(d).is_none(), "second grant to device {d}");
        }
    }

    #[test]
    fn delivery_order_forward_and_reverse() {
        assert_eq!(Static::new(&ctx(), false).delivery_order(), vec![0, 1, 2]);
        assert_eq!(Static::new(&ctx(), true).delivery_order(), vec![2, 1, 0]);
    }

    #[test]
    fn degenerate_single_device_takes_all() {
        let ctx = SchedCtx::new(77, vec![1.0]);
        let mut s = Static::new(&ctx, false);
        assert_eq!(s.next(0), Some(GroupRange::new(0, 77)));
    }

    #[test]
    fn zero_size_partitions_yield_none() {
        // 1 group, 3 devices: two devices get nothing.
        let ctx = SchedCtx::new(1, vec![1.0, 1.0, 1.0]);
        let mut s = Static::new(&ctx, false);
        let got: Vec<bool> = (0..3).map(|d| s.next(d).is_some()).collect();
        assert_eq!(got.iter().filter(|&&b| b).count(), 1);
    }

    #[test]
    fn partition_is_contiguous_front_to_back() {
        let s = Static::new(&ctx(), true); // rev shares the same partition
        let parts = s.partition();
        assert_eq!(parts[0].unwrap().begin, 0);
        assert_eq!(parts[2].unwrap().end, 1000);
        assert_eq!(parts[0].unwrap().end, parts[1].unwrap().begin);
    }
}

//! Rust-side golden implementations of the five kernels.
//!
//! These verify the outputs coming back from the AOT HLO artifacts on the
//! PJRT path (examples + integration tests): the L1 kernels were already
//! validated against the pure-jnp oracles in pytest, and this module
//! closes the loop L3-side.  Float math follows the kernels' f32
//! formulations; comparisons use the tolerances in [`close`].

use super::ray::{self, Sphere};

/// Relative+absolute f32 comparison used by the e2e verifiers.
pub fn close(a: f32, b: f32, rtol: f32, atol: f32) -> bool {
    (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
}

// ----------------------------------------------------------------- gaussian
/// Direct K x K convolution of the haloed slice (same contract as
/// `gaussian_tile`): `img_halo` is (tr + k - 1, w + k - 1) row-major.
pub fn gaussian_blur(img_halo: &[f32], tr: usize, w: usize, filt: &[f32], k: usize) -> Vec<f32> {
    let stride = w + k - 1;
    debug_assert_eq!(img_halo.len(), (tr + k - 1) * stride);
    debug_assert_eq!(filt.len(), k * k);
    let mut out = vec![0.0f32; tr * w];
    for r in 0..tr {
        for c in 0..w {
            let mut acc = 0.0f32;
            for dr in 0..k {
                for dc in 0..k {
                    acc += filt[dr * k + dc] * img_halo[(r + dr) * stride + (c + dc)];
                }
            }
            out[r * w + c] = acc;
        }
    }
    out
}

/// Normalized K x K Gaussian taps — mirrors
/// `python/compile/kernels/gaussian.py::gaussian_weights` in f32.
pub fn gaussian_weights(k: usize, sigma: f32) -> Vec<f32> {
    let mut g = vec![0.0f32; k];
    for (i, gi) in g.iter_mut().enumerate() {
        let r = i as f32 - (k as f32 - 1.0) / 2.0;
        *gi = (-(r * r) / (2.0 * sigma * sigma)).exp();
    }
    let mut w = vec![0.0f32; k * k];
    let mut total = 0.0f32;
    for i in 0..k {
        for j in 0..k {
            w[i * k + j] = g[i] * g[j];
            total += g[i] * g[j];
        }
    }
    for x in &mut w {
        *x /= total;
    }
    w
}

// ----------------------------------------------------------------- binomial
/// CRR European call price, same constants as the kernel
/// (`RATE`/`SIGMA`/`MATURITY` in `binomial.py`), computed with the
/// shrinking-array induction in f64 for a stable reference.
pub fn binomial_price(s0: f32, strike: f32, steps: u32) -> f32 {
    const RATE: f64 = 0.02;
    const SIGMA: f64 = 0.30;
    const MATURITY: f64 = 1.0;
    let n = steps as usize;
    let dt = MATURITY / steps as f64;
    let u = (SIGMA * dt.sqrt()).exp();
    let d = 1.0 / u;
    let p = ((RATE * dt).exp() - d) / (u - d);
    let disc = (-RATE * dt).exp();
    let mut v: Vec<f64> = (0..=n)
        .map(|j| {
            let st = s0 as f64 * (SIGMA * dt.sqrt() * (2.0 * j as f64 - n as f64)).exp();
            (st - strike as f64).max(0.0)
        })
        .collect();
    for m in (1..=n).rev() {
        for j in 0..m {
            v[j] = disc * (p * v[j + 1] + (1.0 - p) * v[j]);
        }
    }
    v[0] as f32
}

// -------------------------------------------------------------------- nbody
/// One integration step for body `i` given all positions/velocities —
/// mirrors `nbody.py` (`EPS2`, `G`, leapfrog-Euler update) in f32.
pub fn nbody_step(
    pos_all: &[[f32; 4]],
    pos: [f32; 4],
    vel: [f32; 4],
    dt: f32,
) -> ([f32; 4], [f32; 4]) {
    const EPS2: f32 = 1e-3;
    const GRAV: f32 = 1.0;
    let mut acc = [0.0f32; 3];
    for pj in pos_all {
        let d = [pj[0] - pos[0], pj[1] - pos[1], pj[2] - pos[2]];
        let r2 = d[0] * d[0] + d[1] * d[1] + d[2] * d[2] + EPS2;
        let inv_r = 1.0 / r2.sqrt();
        let f = GRAV * pj[3] * inv_r * inv_r * inv_r;
        acc[0] += f * d[0];
        acc[1] += f * d[1];
        acc[2] += f * d[2];
    }
    let nv = [vel[0] + acc[0] * dt, vel[1] + acc[1] * dt, vel[2] + acc[2] * dt, vel[3]];
    let np = [pos[0] + nv[0] * dt, pos[1] + nv[1] * dt, pos[2] + nv[2] * dt, pos[3]];
    (np, nv)
}

// ---------------------------------------------------------------------- ray
fn norm3(v: [f32; 3]) -> [f32; 3] {
    let n2 = v[0] * v[0] + v[1] * v[1] + v[2] * v[2];
    let inv = 1.0 / n2.max(1e-24).sqrt();
    [v[0] * inv, v[1] * inv, v[2] * inv]
}

/// Trace one pixel — mirrors `_ray_kernel` (component-wise, two bounces,
/// hard shadows) in f32.
pub fn trace_pixel(rd_in: [f32; 3], spheres: &[Sphere]) -> [f32; 3] {
    let ln = {
        let l = ray::LIGHT_DIR;
        norm3(l)
    };
    let mut rd = norm3(rd_in);
    let mut ro = ray::RAY_ORIGIN;
    let mut col = [0.0f32; 3];
    let mut atten = 1.0f32;

    for _ in 0..ray::BOUNCES {
        let mut t_best = f32::INFINITY;
        let mut hs = [0.0f32; 8];
        for s in spheres {
            let t = ray::intersect(ro, rd, s);
            if t < t_best {
                t_best = t;
                hs = *s;
            }
        }
        let hit = t_best.is_finite();
        let hitf = if hit { 1.0f32 } else { 0.0 };
        let t_safe = if hit { t_best } else { 0.0 };

        let pt = [ro[0] + rd[0] * t_safe, ro[1] + rd[1] * t_safe, ro[2] + rd[2] * t_safe];
        let n = norm3([pt[0] - hs[0], pt[1] - hs[1], pt[2] - hs[2]]);
        let diff = (n[0] * ln[0] + n[1] * ln[1] + n[2] * ln[2]).max(0.0);

        let so = [
            pt[0] + n[0] * ray::SHADOW_EPS,
            pt[1] + n[1] * ray::SHADOW_EPS,
            pt[2] + n[2] * ray::SHADOW_EPS,
        ];
        let mut lit = 1.0f32;
        for s in spheres {
            if ray::intersect(so, ln, s).is_finite() {
                lit = 0.0;
            }
        }

        let shade = ray::AMBIENT + (1.0 - ray::AMBIENT) * diff * lit;
        let contrib = hitf * atten * (1.0 - hs[7]) * shade;
        col[0] += contrib * hs[4];
        col[1] += contrib * hs[5];
        col[2] += contrib * hs[6];

        atten *= hitf * hs[7];
        let dn = rd[0] * n[0] + rd[1] * n[1] + rd[2] * n[2];
        rd = [rd[0] - 2.0 * dn * n[0], rd[1] - 2.0 * dn * n[1], rd[2] - 2.0 * dn * n[2]];
        ro = so;
    }
    [col[0].clamp(0.0, 1.0), col[1].clamp(0.0, 1.0), col[2].clamp(0.0, 1.0)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchsuite::ray::{pixel_ray, scene};

    #[test]
    fn gaussian_identity_filter() {
        // 3x3 identity tap passes the centre through.
        let k = 3;
        let (tr, w) = (2, 4);
        let halo: Vec<f32> = (0..(tr + k - 1) * (w + k - 1)).map(|i| i as f32).collect();
        let mut filt = vec![0.0f32; 9];
        filt[4] = 1.0;
        let out = gaussian_blur(&halo, tr, w, &filt, k);
        let stride = w + k - 1;
        for r in 0..tr {
            for c in 0..w {
                assert_eq!(out[r * w + c], halo[(r + 1) * stride + c + 1]);
            }
        }
    }

    #[test]
    fn gaussian_weights_sum_to_one() {
        let w = gaussian_weights(5, 1.4);
        let s: f32 = w.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!((w[0] - w[24]).abs() < 1e-7, "corner symmetry");
    }

    #[test]
    fn binomial_no_arbitrage_bounds() {
        for (s0, k) in [(50.0, 60.0), (100.0, 60.0), (60.0, 60.0)] {
            let c = binomial_price(s0, k, 255);
            assert!(c >= (s0 - k).max(0.0) - 0.5, "C >= S-K");
            assert!(c <= s0, "C <= S");
        }
        // deep ITM converges to S - K e^{-rT}
        let c = binomial_price(1000.0, 1.0, 255);
        assert!((c - (1000.0 - (0.98f32.powf(0.0) * (-0.02f32).exp()))).abs() < 2.0);
    }

    #[test]
    fn binomial_monotone_in_spot() {
        let a = binomial_price(50.0, 60.0, 64);
        let b = binomial_price(55.0, 60.0, 64);
        assert!(b > a);
    }

    #[test]
    fn nbody_two_body_pull() {
        let pos_all = [[-1.0, 0.0, 0.0, 1.0], [1.0, 0.0, 0.0, 1.0]];
        let (_, v) = nbody_step(&pos_all, pos_all[0], [0.0; 4], 1.0);
        assert!(v[0] > 0.0, "pulled towards +x");
        assert_eq!(v[3], 0.0, "padding lane untouched by forces");
    }

    #[test]
    fn trace_sky_is_black_and_hits_shade() {
        let sph = scene(1);
        let sky = trace_pixel([0.0, 1.0, -0.2], &sph);
        assert_eq!(sky, [0.0, 0.0, 0.0]);
        let w = 64;
        let centre = pixel_ray((w / 2) * w + w / 2, w);
        let hit = trace_pixel(centre, &sph);
        assert!(hit.iter().any(|&c| c > 0.01), "centre pixel shaded: {hit:?}");
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 5e-5, 1e-4, 1e-6));
        assert!(!close(1.0, 1.1, 1e-4, 1e-6));
    }
}

//! Mandelbrot escape-time math, shared by the SimDevice cost profile and
//! the PJRT-path oracle.  The f32 iteration sequence is kept *identical*
//! to the Pallas kernel (`python/compile/kernels/mandelbrot.py`) so the
//! rust oracle matches the AOT artifact bit-for-bit.

use super::profile::CostProfile;
use std::sync::OnceLock;

/// Complex-plane view (classic full-set framing); mirrored by
/// `data::mandelbrot_coords` for the PJRT inputs.
pub const XMIN: f64 = -2.0;
pub const XMAX: f64 = 0.5;
pub const YMIN: f64 = -1.25;
pub const YMAX: f64 = 1.25;

/// f32 escape-time count with the same op order as the Pallas kernel:
/// `zx2 - zy2 + cx`, `2 zx zy + cy`, escape when `zx2 + zy2 > 4`.
pub fn escape_iters(cx: f32, cy: f32, max_iter: u32) -> u32 {
    let (mut zx, mut zy) = (0.0f32, 0.0f32);
    let mut i = 0;
    while i < max_iter {
        let zx2 = zx * zx;
        let zy2 = zy * zy;
        if zx2 + zy2 > 4.0 {
            break;
        }
        let nzx = zx2 - zy2 + cx;
        zy = 2.0 * zx * zy + cy;
        zx = nzx;
        i += 1;
    }
    i
}

/// Map a flattened pixel index to complex coordinates on a W x H grid.
pub fn pixel_to_c(idx: u64, width: u64, height: u64) -> (f32, f32) {
    let x = (idx % width) as f64;
    let y = (idx / width) as f64;
    let cx = XMIN + (x + 0.5) / width as f64 * (XMAX - XMIN);
    let cy = YMIN + (y + 0.5) / height as f64 * (YMAX - YMIN);
    (cx as f32, cy as f32)
}

const SAMPLE_W: u64 = 256;
const SAMPLE_H: u64 = 256;
const SAMPLE_ITERS: u32 = 400;

/// Normalized per-item cost profile along the flattened (row-major) pixel
/// order: the true escape-iteration counts on a coarse sample grid.  This
/// is the irregularity that makes Static mis-balance Mandelbrot in the
/// paper's Fig. 4 — rows crossing the set body cost up to `max_iter`,
/// rows in the escape region are nearly free.
pub fn cost_profile() -> CostProfile {
    static CACHE: OnceLock<CostProfile> = OnceLock::new();
    CACHE
        .get_or_init(|| {
            let mut buckets = Vec::with_capacity((SAMPLE_W * SAMPLE_H) as usize);
            for idx in 0..SAMPLE_W * SAMPLE_H {
                let (cx, cy) = pixel_to_c(idx, SAMPLE_W, SAMPLE_H);
                // +launch/bookkeeping baseline so escaped pixels are cheap
                // but not free.
                buckets.push(1.0 + escape_iters(cx, cy, SAMPLE_ITERS) as f64);
            }
            CostProfile::from_buckets(&buckets)
        })
        .clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_membership() {
        // c = 0 and c = -1 are in the set; c = 1 escapes fast.
        assert_eq!(escape_iters(0.0, 0.0, 100), 100);
        assert_eq!(escape_iters(-1.0, 0.0, 100), 100);
        assert!(escape_iters(1.0, 0.0, 100) < 8);
        assert!(escape_iters(0.3, 0.5, 500) > 10); // near the boundary
    }

    #[test]
    fn pixel_mapping_covers_view() {
        let (cx0, cy0) = pixel_to_c(0, 100, 100);
        assert!(cx0 > XMIN as f32 && cx0 < XMIN as f32 + 0.1);
        assert!(cy0 > YMIN as f32 && cy0 < YMIN as f32 + 0.1);
        let (cx1, cy1) = pixel_to_c(100 * 100 - 1, 100, 100);
        assert!(cx1 < XMAX as f32 && cx1 > XMAX as f32 - 0.1);
        assert!(cy1 < YMAX as f32 && cy1 > YMAX as f32 - 0.1);
    }

    #[test]
    fn profile_center_heavier_than_edges() {
        let p = cost_profile();
        // Middle rows (crossing the set) cost more than the top band.
        let top = p.integral(0.0, 0.1);
        let mid = p.integral(0.45, 0.55);
        assert!(mid > 2.0 * top, "mid {mid} vs top {top}");
    }

    #[test]
    fn profile_is_cached_and_consistent() {
        let a = cost_profile();
        let b = cost_profile();
        assert_eq!(a.resolution(), b.resolution());
        assert!((a.integral(0.2, 0.8) - b.integral(0.2, 0.8)).abs() < 1e-15);
    }
}

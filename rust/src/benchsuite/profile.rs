//! Per-item cost profiles over the flattened work-item index space.
//!
//! The simulator needs `cost([a, b))` for arbitrary item ranges at any
//! problem size.  We store a normalized piecewise-constant profile
//! (mean = 1.0 over [0, 1)) with a prefix-sum table, so range costs are
//! O(1) regardless of range length — this is what keeps the Fig. 5
//! parameter sweep (thousands of simulated runs over 10^8-item problems)
//! inside CI time.

/// Piecewise-constant normalized cost density over [0, 1).
#[derive(Debug, Clone)]
pub struct CostProfile {
    /// prefix[i] = integral of the density over the first i buckets;
    /// prefix[n] == 1.0 by normalization.
    prefix: Vec<f64>,
}

impl CostProfile {
    /// Uniform (regular-kernel) profile.
    pub fn uniform() -> Self {
        Self { prefix: vec![0.0, 1.0] }
    }

    /// Build from raw per-bucket costs (any positive scale; normalized so
    /// the mean density is 1.0).
    pub fn from_buckets(buckets: &[f64]) -> Self {
        assert!(!buckets.is_empty(), "empty cost profile");
        let total: f64 = buckets.iter().sum();
        assert!(total > 0.0, "cost profile sums to zero");
        let mut prefix = Vec::with_capacity(buckets.len() + 1);
        let mut acc = 0.0;
        prefix.push(0.0);
        for &b in buckets {
            debug_assert!(b >= 0.0, "negative bucket cost {b}");
            acc += b / total;
            prefix.push(acc);
        }
        // Guard against floating drift at the right edge.
        *prefix.last_mut().unwrap() = 1.0;
        Self { prefix }
    }

    /// Number of buckets.
    pub fn resolution(&self) -> usize {
        self.prefix.len() - 1
    }

    /// Integral of the normalized density over [a, b) ⊆ [0, 1].
    /// `integral(0, 1) == 1`; for a uniform profile `integral(a, b) == b - a`.
    pub fn integral(&self, a: f64, b: f64) -> f64 {
        let a = a.clamp(0.0, 1.0);
        let b = b.clamp(0.0, 1.0);
        if b <= a {
            return 0.0;
        }
        self.cdf(b) - self.cdf(a)
    }

    /// Cumulative integral over [0, x] with linear interpolation inside a
    /// bucket.
    #[inline]
    fn cdf(&self, x: f64) -> f64 {
        let n = self.prefix.len() - 1;
        let pos = x * n as f64;
        let i = (pos as usize).min(n - 1); // x >= 0 by caller clamp
        let frac = (pos - i as f64).min(1.0);
        // SAFETY-free fast path: i < n, i + 1 <= n by construction.
        let lo = self.prefix[i];
        lo + (self.prefix[i + 1] - lo) * frac
    }

    /// Peak-to-mean ratio — a scalar irregularity measure used in tests
    /// and the Table-1 report.
    pub fn peak_to_mean(&self) -> f64 {
        let n = self.resolution() as f64;
        self.prefix
            .windows(2)
            .map(|w| (w[1] - w[0]) * n)
            .fold(f64::MIN, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_integral_is_length() {
        let p = CostProfile::uniform();
        assert!((p.integral(0.0, 1.0) - 1.0).abs() < 1e-12);
        assert!((p.integral(0.25, 0.75) - 0.5).abs() < 1e-12);
        assert_eq!(p.integral(0.5, 0.5), 0.0);
    }

    #[test]
    fn normalization_makes_total_one() {
        let p = CostProfile::from_buckets(&[3.0, 1.0, 2.0, 2.0]);
        assert!((p.integral(0.0, 1.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn skewed_profile_weights_ranges() {
        // All cost in the first half.
        let p = CostProfile::from_buckets(&[1.0, 1.0, 0.0, 0.0]);
        assert!((p.integral(0.0, 0.5) - 1.0).abs() < 1e-12);
        assert!(p.integral(0.5, 1.0).abs() < 1e-12);
    }

    #[test]
    fn interpolates_within_bucket() {
        let p = CostProfile::from_buckets(&[1.0, 3.0]);
        // Density: 0.5 on [0,0.5), 1.5 on [0.5,1).
        assert!((p.integral(0.0, 0.25) - 0.125).abs() < 1e-12);
        assert!((p.integral(0.5, 0.75) - 0.375).abs() < 1e-12);
    }

    #[test]
    fn integral_is_additive_and_monotone() {
        let p = CostProfile::from_buckets(&[5.0, 1.0, 0.5, 2.0, 4.0]);
        let whole = p.integral(0.1, 0.9);
        let split = p.integral(0.1, 0.37) + p.integral(0.37, 0.9);
        assert!((whole - split).abs() < 1e-12);
        assert!(p.integral(0.1, 0.5) <= p.integral(0.1, 0.9));
    }

    #[test]
    fn out_of_range_clamps() {
        let p = CostProfile::uniform();
        assert!((p.integral(-1.0, 2.0) - 1.0).abs() < 1e-12);
        assert_eq!(p.integral(1.5, 2.0), 0.0);
    }

    #[test]
    fn peak_to_mean_uniform_is_one() {
        assert!((CostProfile::uniform().peak_to_mean() - 1.0).abs() < 1e-12);
        let p = CostProfile::from_buckets(&[1.0, 3.0]);
        assert!((p.peak_to_mean() - 1.5).abs() < 1e-12);
    }
}

//! The five paper benchmarks (six experiment columns — Ray has two
//! scenes), with Table-I properties, per-item cost profiles (the
//! irregularity source for Figs 3–5), transfer footprints, and
//! paper-testbed device-power calibration.
//!
//! Two consumers:
//! * [`crate::sim`] uses [`Bench::profile`] + calibration to produce
//!   deterministic virtual-clock execution times;
//! * [`crate::engine::pjrt`] uses [`data`] to build real tile inputs for
//!   the AOT HLO kernels and [`oracle`] to verify their outputs.

pub mod data;
pub mod mandelbrot;
pub mod oracle;
pub mod profile;
pub mod ray;

use profile::CostProfile;


/// Experiment column identifier (paper Fig. 3 abscissa).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BenchId {
    Gaussian,
    Binomial,
    NBody,
    Ray1,
    Ray2,
    Mandelbrot,
}

impl BenchId {
    pub const ALL: [BenchId; 6] = [
        BenchId::Gaussian,
        BenchId::Binomial,
        BenchId::NBody,
        BenchId::Ray1,
        BenchId::Ray2,
        BenchId::Mandelbrot,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            BenchId::Gaussian => "Gaussian",
            BenchId::Binomial => "Binomial",
            BenchId::NBody => "NBody",
            BenchId::Ray1 => "Ray",
            BenchId::Ray2 => "Ray2",
            BenchId::Mandelbrot => "Mandelbrot",
        }
    }

    /// Paper classification (§V-A): regular vs irregular kernels.
    pub fn is_regular(&self) -> bool {
        matches!(self, BenchId::Gaussian | BenchId::Binomial | BenchId::NBody)
    }

    /// Artifact name in `artifacts/manifest.json` (Ray scenes share one).
    pub fn artifact_name(&self) -> &'static str {
        match self {
            BenchId::Gaussian => "gaussian",
            BenchId::Binomial => "binomial",
            BenchId::NBody => "nbody",
            BenchId::Ray1 | BenchId::Ray2 => "ray",
            BenchId::Mandelbrot => "mandelbrot",
        }
    }
}

/// Table I row: the static properties of a benchmark.
#[derive(Debug, Clone)]
pub struct BenchProps {
    pub name: &'static str,
    pub lws: u32,
    pub read_buffers: u32,
    pub write_buffers: u32,
    /// outputs : work-items ratio, e.g. Binomial 1:255, Mandelbrot 4:1.
    pub out_pattern: (u32, u32),
    pub kernel_args: u32,
    pub local_mem: bool,
    pub custom_types: bool,
    /// Paper "Size" row, in the paper's own units (px / samples / bodies).
    pub size_label: &'static str,
    pub other_params: &'static str,
}

/// A fully-instantiated benchmark: Table-I properties + simulation
/// calibration + cost profile.
#[derive(Debug, Clone)]
pub struct Bench {
    pub id: BenchId,
    pub props: BenchProps,
    /// Default problem size in work-items — chosen, like the paper, so the
    /// fastest device (GPU) completes the ROI in ~2 s.
    pub default_gws: u64,
    /// True relative device throughputs [CPU, iGPU, GPU] (GPU = 1).  The
    /// *scheduler* sees these same values as its `P_i` estimates; on
    /// irregular kernels the spatial profile still breaks Static.
    pub true_powers: [f64; 3],
    /// GPU throughput in cost-units/second (mean item cost is ~1 unit, so
    /// this is roughly items/second); calibrates the 2-second target.
    pub gpu_units_per_sec: f64,
    /// Normalized per-item cost along the flattened index space.
    pub profile: CostProfile,
    /// Host<->device traffic per work-item (input, output), in bytes.
    pub bytes_in_per_item: f64,
    pub bytes_out_per_item: f64,
    /// Per-package broadcast input (NBody ships the full position set with
    /// every package — the paper's "communications" overhead).
    pub bytes_in_per_package: f64,
}

impl Bench {
    /// Instantiate one benchmark with its paper calibration.
    pub fn new(id: BenchId) -> Self {
        match id {
            BenchId::Gaussian => Bench {
                id,
                props: BenchProps {
                    name: "Gaussian",
                    lws: 128,
                    read_buffers: 2,
                    write_buffers: 1,
                    out_pattern: (1, 1),
                    kernel_args: 6,
                    local_mem: false,
                    custom_types: false,
                    size_label: "8192px",
                    other_params: "31px",
                },
                // 8192 x 8192 pixels.
                default_gws: 8192 * 8192,
                // Memory-bound stencil: iGPU's shared DDR3 helps it less;
                // 2-core CPU is weak.
                true_powers: [0.12, 0.45, 1.0],
                gpu_units_per_sec: 8192.0 * 8192.0 / 2.0,
                profile: CostProfile::uniform(),
                bytes_in_per_item: 4.0, // one f32 pixel (+ tiny filter)
                bytes_out_per_item: 4.0,
                bytes_in_per_package: 31.0 * 31.0 * 4.0, // filter taps
            },
            BenchId::Binomial => Bench {
                id,
                props: BenchProps {
                    name: "Binomial",
                    lws: 255,
                    read_buffers: 1,
                    write_buffers: 1,
                    out_pattern: (1, 255),
                    kernel_args: 5,
                    local_mem: true,
                    custom_types: false,
                    size_label: "4194304",
                    other_params: "",
                },
                default_gws: 4_194_304,
                // Lattice induction is serial-ish per group: GPUs dominate.
                true_powers: [0.08, 0.35, 1.0],
                gpu_units_per_sec: 4_194_304.0 / 2.0,
                profile: CostProfile::uniform(),
                bytes_in_per_item: 8.0 / 255.0, // (S0, K) per option
                bytes_out_per_item: 4.0 / 255.0, // one price per option
                bytes_in_per_package: 0.0,
            },
            BenchId::NBody => Bench {
                id,
                props: BenchProps {
                    name: "NBody",
                    lws: 64,
                    read_buffers: 2,
                    write_buffers: 2,
                    out_pattern: (1, 1),
                    kernel_args: 7,
                    local_mem: false,
                    custom_types: false,
                    size_label: "229376",
                    other_params: "",
                },
                default_gws: 229_376,
                // All-pairs O(N) per item: raw FLOPs decide; CPU is worst.
                true_powers: [0.05, 0.40, 1.0],
                gpu_units_per_sec: 229_376.0 / 2.0,
                profile: CostProfile::uniform(),
                bytes_in_per_item: 32.0, // pos + vel float4
                bytes_out_per_item: 32.0,
                // every package re-reads the full position set
                bytes_in_per_package: 229_376.0 * 16.0,
            },
            BenchId::Ray1 | BenchId::Ray2 => {
                let scene = if id == BenchId::Ray1 { 1 } else { 2 };
                Bench {
                    id,
                    props: BenchProps {
                        name: if scene == 1 { "Ray" } else { "Ray2" },
                        lws: 128,
                        read_buffers: 1,
                        write_buffers: 1,
                        out_pattern: (1, 1),
                        kernel_args: 11,
                        local_mem: true,
                        custom_types: true,
                        size_label: "4096px",
                        other_params: "scene",
                    },
                    default_gws: 4096 * 4096,
                    // Divergent control flow: the 4-thread CPU copes
                    // comparatively well, SIMT GPUs lose efficiency.
                    true_powers: [0.20, 0.35, 1.0],
                    gpu_units_per_sec: 4096.0 * 4096.0 / 2.0,
                    profile: ray::cost_profile(scene),
                    bytes_in_per_item: 0.1, // scene buffer amortized
                    bytes_out_per_item: 4.0,
                    bytes_in_per_package: 6.0 * 32.0, // sphere structs
                }
            }
            BenchId::Mandelbrot => Bench {
                id,
                props: BenchProps {
                    name: "Mandelbrot",
                    lws: 256,
                    read_buffers: 0,
                    write_buffers: 1,
                    out_pattern: (4, 1),
                    kernel_args: 8,
                    local_mem: false,
                    custom_types: false,
                    size_label: "14336px",
                    other_params: "5000",
                },
                default_gws: 14_336 * 14_336,
                true_powers: [0.15, 0.40, 1.0],
                gpu_units_per_sec: 14_336.0 * 14_336.0 / 2.0,
                profile: mandelbrot::cost_profile(),
                bytes_in_per_item: 0.0,
                bytes_out_per_item: 4.0, // RGBA (the 4:1 out pattern)
                bytes_in_per_package: 0.0,
            },
        }
    }

    /// All six experiment columns, in paper order.
    pub fn all() -> Vec<Bench> {
        BenchId::ALL.iter().map(|&id| Bench::new(id)).collect()
    }

    /// Work-groups for a given global size.
    pub fn groups(&self, gws: u64) -> u64 {
        gws.div_ceil(self.props.lws as u64)
    }

    /// Simulated compute cost (in cost units) of an item range at problem
    /// size `gws` — the profile integral scaled to absolute items.
    pub fn range_cost(&self, range: crate::types::ItemRange, gws: u64) -> f64 {
        let a = range.begin as f64 / gws as f64;
        let b = (range.end.min(gws)) as f64 / gws as f64;
        self.profile.integral(a, b) * gws as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::ItemRange;

    #[test]
    fn all_has_six_columns_in_paper_order() {
        let all = Bench::all();
        assert_eq!(all.len(), 6);
        assert_eq!(all[0].props.name, "Gaussian");
        assert_eq!(all[5].props.name, "Mandelbrot");
    }

    #[test]
    fn table1_properties_match_paper() {
        let g = Bench::new(BenchId::Gaussian);
        assert_eq!((g.props.lws, g.props.read_buffers, g.props.write_buffers), (128, 2, 1));
        let b = Bench::new(BenchId::Binomial);
        assert_eq!(b.props.out_pattern, (1, 255));
        assert!(b.props.local_mem);
        let n = Bench::new(BenchId::NBody);
        assert_eq!(n.props.lws, 64);
        assert_eq!((n.props.read_buffers, n.props.write_buffers), (2, 2));
        let r = Bench::new(BenchId::Ray1);
        assert_eq!(r.props.kernel_args, 11);
        assert!(r.props.custom_types);
        let m = Bench::new(BenchId::Mandelbrot);
        assert_eq!(m.props.out_pattern, (4, 1));
        assert_eq!(m.props.read_buffers, 0);
    }

    #[test]
    fn regular_irregular_split_matches_paper() {
        assert!(BenchId::Gaussian.is_regular());
        assert!(BenchId::Binomial.is_regular());
        assert!(BenchId::NBody.is_regular());
        assert!(!BenchId::Ray1.is_regular());
        assert!(!BenchId::Ray2.is_regular());
        assert!(!BenchId::Mandelbrot.is_regular());
    }

    #[test]
    fn gpu_finishes_default_size_in_two_seconds() {
        for b in Bench::all() {
            let t = b.range_cost(ItemRange::new(0, b.default_gws), b.default_gws)
                / b.gpu_units_per_sec;
            assert!((t - 2.0).abs() < 0.25, "{}: {t}s", b.props.name);
        }
    }

    #[test]
    fn range_cost_is_additive() {
        let b = Bench::new(BenchId::Mandelbrot);
        let gws = b.default_gws;
        let whole = b.range_cost(ItemRange::new(0, gws), gws);
        let half1 = b.range_cost(ItemRange::new(0, gws / 2), gws);
        let half2 = b.range_cost(ItemRange::new(gws / 2, gws), gws);
        assert!((whole - (half1 + half2)).abs() / whole < 1e-9);
    }

    #[test]
    fn irregular_profiles_are_nonuniform() {
        for id in [BenchId::Ray1, BenchId::Ray2, BenchId::Mandelbrot] {
            let b = Bench::new(id);
            let gws = b.default_gws;
            let q: Vec<f64> = (0..4)
                .map(|i| {
                    b.range_cost(ItemRange::new(i * gws / 4, (i + 1) * gws / 4), gws)
                })
                .collect();
            let spread = q.iter().cloned().fold(f64::MIN, f64::max)
                / q.iter().cloned().fold(f64::MAX, f64::min);
            assert!(spread > 1.05, "{:?} spread {spread}", id);
        }
    }

    #[test]
    fn groups_round_up() {
        let b = Bench::new(BenchId::Binomial);
        assert_eq!(b.groups(255), 1);
        assert_eq!(b.groups(256), 2);
        assert_eq!(b.groups(4_194_304), 4_194_304_u64.div_ceil(255));
    }
}

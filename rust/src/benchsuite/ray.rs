//! Ray benchmark scene data + intersection math, shared by the SimDevice
//! cost profile and the PJRT-path oracle.  Scene constants MUST stay in
//! sync with `python/compile/model.py::demo_scene`.

use super::profile::CostProfile;
use std::sync::OnceLock;

/// Camera/light constants — mirror `python/compile/kernels/ray.py`.
pub const RAY_ORIGIN: [f32; 3] = [0.0, 0.0, -3.0];
pub const LIGHT_DIR: [f32; 3] = [0.45, 0.8, -0.4];
pub const AMBIENT: f32 = 0.1;
pub const BOUNCES: usize = 2;
pub const SHADOW_EPS: f32 = 1e-3;

/// One sphere: centre xyz, radius, rgb, reflectivity.
pub type Sphere = [f32; 8];

/// Scene 1 (paper "Ray"): mixed diffuse scene with a ground sphere.
pub fn scene(variant: u8) -> Vec<Sphere> {
    match variant {
        1 => vec![
            [0.0, -100.5, 1.0, 100.0, 0.6, 0.6, 0.6, 0.05],
            [0.0, 0.0, 1.0, 0.5, 0.9, 0.2, 0.2, 0.30],
            [-1.1, 0.0, 1.2, 0.5, 0.2, 0.9, 0.2, 0.10],
            [1.1, 0.0, 1.2, 0.5, 0.2, 0.2, 0.9, 0.60],
            [0.0, 1.0, 2.0, 0.6, 0.9, 0.9, 0.2, 0.80],
            [-0.5, -0.3, 0.4, 0.15, 0.9, 0.9, 0.9, 0.00],
        ],
        2 => vec![
            [0.0, -100.5, 1.0, 100.0, 0.5, 0.5, 0.7, 0.40],
            [-0.8, 0.0, 0.9, 0.45, 0.9, 0.4, 0.1, 0.70],
            [0.8, 0.0, 0.9, 0.45, 0.1, 0.4, 0.9, 0.70],
            [0.0, 0.8, 1.4, 0.45, 0.4, 0.9, 0.1, 0.70],
            [0.0, -0.2, 0.5, 0.20, 0.95, 0.95, 0.95, 0.90],
            [0.0, 2.2, 2.2, 0.80, 0.8, 0.8, 0.2, 0.20],
        ],
        v => panic!("unknown ray scene variant {v}"),
    }
}

/// Ray/sphere hit distance with the kernel's exact formulation
/// (`t0 = -b - sqrt(disc)`, fall back to `t1`); +inf where missed.
pub fn intersect(ro: [f32; 3], rd: [f32; 3], s: &Sphere) -> f32 {
    let oc = [ro[0] - s[0], ro[1] - s[1], ro[2] - s[2]];
    let b = oc[0] * rd[0] + oc[1] * rd[1] + oc[2] * rd[2];
    let c = oc[0] * oc[0] + oc[1] * oc[1] + oc[2] * oc[2] - s[3] * s[3];
    let disc = b * b - c;
    let sq = disc.max(0.0).sqrt();
    let t0 = -b - sq;
    let t1 = -b + sq;
    let t = if t0 > SHADOW_EPS { t0 } else { t1 };
    if disc > 0.0 && t > SHADOW_EPS {
        t
    } else {
        f32::INFINITY
    }
}

/// Primary-ray direction for a flattened pixel index on a W-wide square
/// image — mirrors `python/compile/model.py::pixel_rays` (un-normalized;
/// the kernel normalizes).
pub fn pixel_ray(idx: u64, width: u64) -> [f32; 3] {
    let x = (idx % width) as f32;
    let y = (idx / width) as f32;
    let u = (x + 0.5) / width as f32 * 2.0 - 1.0;
    let v = (y + 0.5) / width as f32 * 2.0 - 1.0;
    [u, -v, 1.0]
}

fn norm3(v: [f32; 3]) -> [f32; 3] {
    let n = (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt().max(1e-12);
    [v[0] / n, v[1] / n, v[2] / n]
}

/// Relative tracing cost of one pixel: intersection tests + shading work
/// along the actual bounce path.  This is the paper's per-pixel
/// irregularity (scene-dependent — scene 2 is more reflective, so paths
/// are deeper on average).
pub fn pixel_cost(idx: u64, width: u64, spheres: &[Sphere]) -> f64 {
    let mut rd = norm3(pixel_ray(idx, width));
    let mut ro = RAY_ORIGIN;
    let mut cost = 1.0; // primary ray setup
    for _ in 0..BOUNCES {
        cost += spheres.len() as f64; // nearest-hit tests
        let mut t_best = f32::INFINITY;
        let mut best: Option<&Sphere> = None;
        for s in spheres {
            let t = intersect(ro, rd, s);
            if t < t_best {
                t_best = t;
                best = Some(s);
            }
        }
        let Some(s) = best else { break };
        if !t_best.is_finite() {
            break;
        }
        // shading + shadow tests only on hit
        cost += 2.0 + spheres.len() as f64;
        let pt = [ro[0] + rd[0] * t_best, ro[1] + rd[1] * t_best, ro[2] + rd[2] * t_best];
        let n = norm3([pt[0] - s[0], pt[1] - s[1], pt[2] - s[2]]);
        if s[7] <= 0.0 {
            break; // non-reflective: path ends
        }
        let dn = rd[0] * n[0] + rd[1] * n[1] + rd[2] * n[2];
        rd = [rd[0] - 2.0 * dn * n[0], rd[1] - 2.0 * dn * n[1], rd[2] - 2.0 * dn * n[2]];
        ro = [
            pt[0] + n[0] * SHADOW_EPS,
            pt[1] + n[1] * SHADOW_EPS,
            pt[2] + n[2] * SHADOW_EPS,
        ];
    }
    cost
}

const SAMPLE_W: u64 = 128;

/// Cost profile along the flattened pixel order for a scene variant.
pub fn cost_profile(variant: u8) -> CostProfile {
    static CACHE1: OnceLock<CostProfile> = OnceLock::new();
    static CACHE2: OnceLock<CostProfile> = OnceLock::new();
    let cache = if variant == 1 { &CACHE1 } else { &CACHE2 };
    cache
        .get_or_init(|| {
            let spheres = scene(variant);
            let buckets: Vec<f64> = (0..SAMPLE_W * SAMPLE_W)
                .map(|idx| pixel_cost(idx, SAMPLE_W, &spheres))
                .collect();
            CostProfile::from_buckets(&buckets)
        })
        .clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenes_have_six_spheres_and_sane_fields() {
        for v in [1, 2] {
            let s = scene(v);
            assert_eq!(s.len(), 6);
            for sp in &s {
                assert!(sp[3] > 0.0, "radius positive");
                assert!((0.0..=1.0).contains(&sp[7]), "reflectivity in unit range");
            }
        }
    }

    #[test]
    fn head_on_intersection_distance() {
        let s: Sphere = [0.0, 0.0, 5.0, 1.0, 1.0, 1.0, 1.0, 0.0];
        let t = intersect([0.0, 0.0, 0.0], [0.0, 0.0, 1.0], &s);
        assert!((t - 4.0).abs() < 1e-5);
    }

    #[test]
    fn miss_is_infinite() {
        let s: Sphere = [0.0, 0.0, 5.0, 1.0, 1.0, 1.0, 1.0, 0.0];
        assert!(!intersect([0.0, 0.0, 0.0], [0.0, 0.0, -1.0], &s).is_finite());
        assert!(!intersect([0.0, 0.0, 0.0], [0.0, 1.0, 0.0], &s).is_finite());
    }

    #[test]
    fn hit_pixels_cost_more_than_sky() {
        let sph = scene(1);
        // centre of image hits the red sphere; top-left corner is sky
        let w = 64;
        let centre = (w / 2) * w + w / 2;
        assert!(pixel_cost(centre, w, &sph) > pixel_cost(0, w, &sph));
    }

    #[test]
    fn scene2_is_costlier_on_average() {
        let w = 64;
        let (s1, s2) = (scene(1), scene(2));
        let c1: f64 = (0..w * w).map(|i| pixel_cost(i, w, &s1)).sum();
        let c2: f64 = (0..w * w).map(|i| pixel_cost(i, w, &s2)).sum();
        assert!(c2 > c1, "scene2 {c2} <= scene1 {c1}");
    }

    #[test]
    fn profiles_differ_between_scenes() {
        let p1 = cost_profile(1);
        let p2 = cost_profile(2);
        let d: f64 = (0..10)
            .map(|i| {
                let a = i as f64 / 10.0;
                (p1.integral(a, a + 0.1) - p2.integral(a, a + 0.1)).abs()
            })
            .sum();
        assert!(d > 1e-3);
    }
}

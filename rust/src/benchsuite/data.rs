//! Host-side problem data for the PJRT execution path.
//!
//! A [`Problem`] owns the full input buffers of one benchmark at a chosen
//! problem scale (exact multiples of the artifact tile size), slices tile
//! inputs for each HLO invocation, and verifies device outputs against the
//! [`super::oracle`] implementations.  This is EngineCL's buffer-slicing
//! role, performed by the rust coordinator.

use super::{mandelbrot, oracle, ray, BenchId};
use crate::runtime::HostArray;
use crate::stats::XorShift64;
use anyhow::{bail, Result};

/// Benchmark-specific payload + tile geometry for the PJRT path.
#[derive(Debug, Clone)]
pub struct Problem {
    pub bench: BenchId,
    /// Total work-items (exact multiple of `tile_items`).
    pub gws: u64,
    /// Work-items per artifact invocation (from the manifest).
    pub tile_items: u64,
    payload: Payload,
}

#[derive(Debug, Clone)]
enum Payload {
    Mandelbrot {
        width: u64,
        height: u64,
    },
    Gaussian {
        /// padded image (rows + k - 1) x (width + k - 1), row-major
        img: Vec<f32>,
        filt: Vec<f32>,
        width: usize,
        k: usize,
        tile_rows: usize,
    },
    Binomial {
        s0: Vec<f32>,
        strike: Vec<f32>,
        steps: u32,
        options_per_tile: usize,
    },
    NBody {
        pos: Vec<f32>, // (N, 4) row-major
        vel: Vec<f32>,
        n: usize,
        dt: f32,
    },
    Ray {
        scene: Vec<ray::Sphere>,
        width: u64,
    },
}

impl Problem {
    /// Build a problem sized `tiles * tile_items` work-items.
    /// `constants` comes from the artifact manifest entry.
    pub fn new(
        bench: BenchId,
        tiles: u64,
        manifest: &crate::runtime::ManifestEntry,
        seed: u64,
    ) -> Result<Self> {
        let tile_items = manifest.tile_items;
        let gws = tiles * tile_items;
        let c = &manifest.constants;
        let payload = match bench {
            BenchId::Mandelbrot => {
                // Square-ish view: width fixed at 1024 px.
                let width = 1024u64;
                if gws % width != 0 {
                    bail!("mandelbrot gws {gws} not a multiple of width {width}");
                }
                Payload::Mandelbrot { width, height: gws / width }
            }
            BenchId::Gaussian => {
                let tile_rows = c["tile_rows"].as_u64().unwrap() as usize;
                let width = c["width"].as_u64().unwrap() as usize;
                let k = c["k"].as_u64().unwrap() as usize;
                let sigma = c["sigma"].as_f64().unwrap() as f32;
                let rows = (tiles as usize) * tile_rows;
                let (h, w) = (rows + k - 1, width + k - 1);
                let mut rng = XorShift64::new(seed);
                let img: Vec<f32> =
                    (0..h * w).map(|_| rng.next_f64() as f32).collect();
                let _ = rows;
                Payload::Gaussian {
                    img,
                    filt: oracle::gaussian_weights(k, sigma),
                    width,
                    k,
                    tile_rows,
                }
            }
            BenchId::Binomial => {
                let steps = c["steps"].as_u64().unwrap() as u32;
                let options_per_tile = c["options"].as_u64().unwrap() as usize;
                let n_opt = tiles as usize * options_per_tile;
                let mut rng = XorShift64::new(seed);
                let s0: Vec<f32> =
                    (0..n_opt).map(|_| rng.uniform(10.0, 150.0) as f32).collect();
                let strike: Vec<f32> =
                    (0..n_opt).map(|_| rng.uniform(10.0, 150.0) as f32).collect();
                Payload::Binomial { s0, strike, steps, options_per_tile }
            }
            BenchId::NBody => {
                let n = c["n"].as_u64().unwrap() as usize;
                let dt = c["dt"].as_f64().unwrap() as f32;
                if gws != n as u64 {
                    bail!("nbody gws {gws} must equal N {n} (tiles * tile)");
                }
                let mut rng = XorShift64::new(seed);
                let mut pos = Vec::with_capacity(n * 4);
                let mut vel = Vec::with_capacity(n * 4);
                for _ in 0..n {
                    for _ in 0..3 {
                        pos.push(rng.uniform(-1.0, 1.0) as f32);
                        vel.push(rng.uniform(-0.1, 0.1) as f32);
                    }
                    pos.push(rng.uniform(0.1, 1.0) as f32); // mass
                    vel.push(0.0);
                }
                Payload::NBody { pos, vel, n, dt }
            }
            BenchId::Ray1 | BenchId::Ray2 => {
                let width = 256u64;
                if gws % width != 0 {
                    bail!("ray gws {gws} not a multiple of width {width}");
                }
                let variant = if bench == BenchId::Ray1 { 1 } else { 2 };
                Payload::Ray { scene: ray::scene(variant), width }
            }
        };
        Ok(Self { bench, gws, tile_items, payload })
    }

    pub fn tiles(&self) -> u64 {
        self.gws / self.tile_items
    }

    /// Whether artifact input `i` is loop-invariant across tiles (filter
    /// taps, scene buffer, full position set).  The PJRT backend's
    /// *buffers* optimization uploads these once per device.
    pub fn input_is_constant(&self, i: usize) -> bool {
        match &self.payload {
            Payload::Mandelbrot { .. } | Payload::Binomial { .. } => false,
            Payload::Gaussian { .. } => i == 1, // filter
            Payload::NBody { .. } => i == 0,    // pos_all
            Payload::Ray { .. } => i == 1,      // scene
        }
    }

    /// Input arrays for the artifact invocation covering items
    /// `[tile * tile_items, (tile + 1) * tile_items)`.
    pub fn tile_inputs(&self, tile: u64) -> Vec<HostArray> {
        let t = self.tile_items;
        let begin = tile * t;
        match &self.payload {
            Payload::Mandelbrot { width, height } => {
                let mut cx = Vec::with_capacity(t as usize);
                let mut cy = Vec::with_capacity(t as usize);
                for i in begin..begin + t {
                    let (x, y) = mandelbrot::pixel_to_c(i, *width, *height);
                    cx.push(x);
                    cy.push(y);
                }
                vec![HostArray::f32(vec![t as usize], cx), HostArray::f32(vec![t as usize], cy)]
            }
            Payload::Gaussian { img, filt, width, k, tile_rows, .. } => {
                let stride = width + k - 1;
                let r0 = tile as usize * tile_rows;
                let slice_rows = tile_rows + k - 1;
                let halo: Vec<f32> =
                    img[r0 * stride..(r0 + slice_rows) * stride].to_vec();
                vec![
                    HostArray::f32(vec![slice_rows, stride], halo),
                    HostArray::f32(vec![*k, *k], filt.clone()),
                ]
            }
            Payload::Binomial { s0, strike, options_per_tile, .. } => {
                let o0 = tile as usize * options_per_tile;
                let o1 = o0 + options_per_tile;
                vec![
                    HostArray::f32(vec![*options_per_tile], s0[o0..o1].to_vec()),
                    HostArray::f32(vec![*options_per_tile], strike[o0..o1].to_vec()),
                ]
            }
            Payload::NBody { pos, vel, n, .. } => {
                let b0 = begin as usize;
                let b1 = (begin + t) as usize;
                vec![
                    HostArray::f32(vec![*n, 4], pos.clone()),
                    HostArray::f32(vec![t as usize, 4], pos[b0 * 4..b1 * 4].to_vec()),
                    HostArray::f32(vec![t as usize, 4], vel[b0 * 4..b1 * 4].to_vec()),
                ]
            }
            Payload::Ray { scene, width } => {
                let mut rd = Vec::with_capacity(t as usize * 3);
                for i in begin..begin + t {
                    let d = ray::pixel_ray(i, *width);
                    rd.extend_from_slice(&d);
                }
                let mut sph = Vec::with_capacity(scene.len() * 8);
                for s in scene {
                    sph.extend_from_slice(s);
                }
                vec![
                    HostArray::f32(vec![t as usize, 3], rd),
                    HostArray::f32(vec![scene.len(), 8], sph),
                ]
            }
        }
    }

    /// Verify a sample of `samples` items of a tile's outputs against the
    /// rust oracle.  Returns the number of mismatching sampled items.
    pub fn verify_tile(&self, tile: u64, outputs: &[HostArray], samples: u64) -> usize {
        let t = self.tile_items;
        let begin = tile * t;
        let mut rng = XorShift64::new(0xC0FFEE ^ tile);
        let mut bad = 0usize;
        match &self.payload {
            Payload::Mandelbrot { width, height } => {
                let out = outputs[0].as_i32();
                for _ in 0..samples {
                    let j = rng.below(t);
                    let (cx, cy) = mandelbrot::pixel_to_c(begin + j, *width, *height);
                    let want = mandelbrot::escape_iters(cx, cy, 200) as i32;
                    if out[j as usize] != want {
                        bad += 1;
                    }
                }
            }
            Payload::Gaussian { img, filt, width, k, tile_rows, .. } => {
                let out = outputs[0].as_f32();
                let stride = width + k - 1;
                let r0 = tile as usize * tile_rows;
                let halo = &img[r0 * stride..(r0 + tile_rows + k - 1) * stride];
                let want = oracle::gaussian_blur(halo, *tile_rows, *width, filt, *k);
                for _ in 0..samples {
                    let j = rng.below((tile_rows * width) as u64) as usize;
                    if !oracle::close(out[j], want[j], 1e-4, 1e-5) {
                        bad += 1;
                    }
                }
            }
            Payload::Binomial { s0, strike, steps, options_per_tile } => {
                let out = outputs[0].as_f32();
                let o0 = tile as usize * options_per_tile;
                for _ in 0..samples {
                    let j = rng.below(*options_per_tile as u64) as usize;
                    let want = oracle::binomial_price(s0[o0 + j], strike[o0 + j], *steps);
                    if !oracle::close(out[j], want, 5e-3, 1e-2) {
                        bad += 1;
                    }
                }
            }
            Payload::NBody { pos, vel, n, dt } => {
                let op = outputs[0].as_f32();
                let ov = outputs[1].as_f32();
                let all: Vec<[f32; 4]> = (0..*n)
                    .map(|i| [pos[i * 4], pos[i * 4 + 1], pos[i * 4 + 2], pos[i * 4 + 3]])
                    .collect();
                for _ in 0..samples {
                    let j = rng.below(t) as usize;
                    let gi = begin as usize + j;
                    let p = all[gi];
                    let v = [vel[gi * 4], vel[gi * 4 + 1], vel[gi * 4 + 2], vel[gi * 4 + 3]];
                    let (wp, wv) = oracle::nbody_step(&all, p, v, *dt);
                    for c in 0..4 {
                        if !oracle::close(op[j * 4 + c], wp[c], 1e-3, 1e-4)
                            || !oracle::close(ov[j * 4 + c], wv[c], 1e-3, 1e-4)
                        {
                            bad += 1;
                            break;
                        }
                    }
                }
            }
            Payload::Ray { scene, width } => {
                let out = outputs[0].as_f32();
                for _ in 0..samples {
                    let j = rng.below(t) as usize;
                    let rd = ray::pixel_ray(begin + j as u64, *width);
                    let want = oracle::trace_pixel(rd, scene);
                    for c in 0..3 {
                        if !oracle::close(out[j * 3 + c], want[c], 1e-3, 1e-3) {
                            bad += 1;
                            break;
                        }
                    }
                }
            }
        }
        bad
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonio::Json;
    use crate::runtime::ManifestEntry;

    fn entry(bench: BenchId) -> ManifestEntry {
        // Mirror artifacts/manifest.json geometry without needing the file.
        let (tile_items, constants) = match bench {
            BenchId::Mandelbrot => (2048, r#"{"max_iter": 200, "block": 256}"#),
            BenchId::Gaussian => {
                (4096, r#"{"tile_rows": 8, "width": 512, "k": 5, "sigma": 1.4}"#)
            }
            BenchId::Binomial => (65280, r#"{"steps": 255, "options": 256}"#),
            BenchId::NBody => (256, r#"{"n": 2048, "dt": 1e-3}"#),
            BenchId::Ray1 | BenchId::Ray2 => {
                (1024, r#"{"spheres": 6, "width": 64, "bounces": 2}"#)
            }
        };
        ManifestEntry {
            name: bench.artifact_name().into(),
            file: format!("{}.hlo.txt", bench.artifact_name()),
            tile_items,
            lws: 0,
            inputs: vec![],
            outputs: vec![],
            constants: Json::parse(constants).unwrap().as_obj().unwrap().clone(),
            sha256: String::new(),
        }
    }

    #[test]
    fn mandelbrot_tile_inputs_have_coords() {
        let p = Problem::new(BenchId::Mandelbrot, 4, &entry(BenchId::Mandelbrot), 1).unwrap();
        assert_eq!(p.tiles(), 4);
        let ins = p.tile_inputs(1);
        assert_eq!(ins.len(), 2);
        assert_eq!(ins[0].dims, vec![2048]);
        // Second tile starts at item 2048 -> pixel (0, 2) on a 1024-wide grid
        let (cx, _) = mandelbrot::pixel_to_c(2048, 1024, p.gws / 1024);
        assert_eq!(ins[0].as_f32()[0], cx);
    }

    #[test]
    fn gaussian_tile_slices_with_halo() {
        let p = Problem::new(BenchId::Gaussian, 3, &entry(BenchId::Gaussian), 2).unwrap();
        let ins = p.tile_inputs(2);
        assert_eq!(ins[0].dims, vec![12, 516]); // 8 + 4 halo rows
        assert_eq!(ins[1].dims, vec![5, 5]);
    }

    #[test]
    fn binomial_tiles_slice_options() {
        let p = Problem::new(BenchId::Binomial, 2, &entry(BenchId::Binomial), 3).unwrap();
        assert_eq!(p.gws, 2 * 65280);
        let i0 = p.tile_inputs(0);
        let i1 = p.tile_inputs(1);
        assert_eq!(i0[0].dims, vec![256]);
        assert_ne!(i0[0].as_f32()[0], i1[0].as_f32()[0]);
    }

    #[test]
    fn nbody_requires_full_problem() {
        let e = entry(BenchId::NBody);
        assert!(Problem::new(BenchId::NBody, 4, &e, 1).is_err()); // 1024 != 2048
        let p = Problem::new(BenchId::NBody, 8, &e, 1).unwrap();
        let ins = p.tile_inputs(7);
        assert_eq!(ins[0].dims, vec![2048, 4]);
        assert_eq!(ins[1].dims, vec![256, 4]);
    }

    #[test]
    fn ray_scene_variant_changes_inputs() {
        let p1 = Problem::new(BenchId::Ray1, 2, &entry(BenchId::Ray1), 1).unwrap();
        let p2 = Problem::new(BenchId::Ray2, 2, &entry(BenchId::Ray2), 1).unwrap();
        let s1 = &p1.tile_inputs(0)[1];
        let s2 = &p2.tile_inputs(0)[1];
        assert_ne!(s1.as_f32(), s2.as_f32());
    }

    #[test]
    fn verify_accepts_oracle_outputs() {
        // Feed the oracle's own answers through verify_tile: zero mismatches.
        let p = Problem::new(BenchId::Mandelbrot, 1, &entry(BenchId::Mandelbrot), 1).unwrap();
        let mut out = Vec::with_capacity(2048);
        for i in 0..2048u64 {
            let (cx, cy) = mandelbrot::pixel_to_c(i, 1024, 2);
            out.push(mandelbrot::escape_iters(cx, cy, 200) as i32);
        }
        let arr = HostArray::i32(vec![2048], out);
        assert_eq!(p.verify_tile(0, &[arr], 64), 0);
    }
}

//! Run configuration: a JSON-backed description of an experiment that the
//! CLI loads (`--config run.json`) or builds from flags.  This is the
//! "launcher" layer — everything an `enginecl run` needs lives in one
//! [`RunConfig`] value.  Parsing uses the in-tree [`crate::jsonio`]
//! module (no serde in this offline environment).

use crate::benchsuite::BenchId;
use crate::jsonio::Json;
use crate::scheduler::{AdaptiveParams, HGuidedParams, SchedulerKind};
use crate::types::{
    ContentionModel, DeviceClass, DeviceSpec, ExecMode, MaskPolicy, Optimizations,
};
use anyhow::{anyhow, bail, Context, Result};

/// A complete experiment description.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub bench: String,
    pub gws: Option<u64>,
    pub scheduler: SchedulerKind,
    pub mode: String, // "roi" | "binary"
    pub init_overlap: bool,
    pub buffer_flags: bool,
    /// Pipeline extension: measured-throughput feedback into the next
    /// iteration's scheduler estimates (off = the paper's runtime).
    pub estimate_refine: bool,
    /// Pipeline extension: per-stage device-mask selection policy
    /// ("fixed" | "min-energy" | "min-time" | "energy-under-deadline");
    /// "fixed" = the spec masks verbatim.
    pub mask_policy: String,
    /// Pipeline extension: co-execution contention scope ("view" |
    /// "pool"); "view" = the legacy per-stage-view retention.
    pub contention: String,
    pub reps: usize,
    pub devices: Option<Vec<DeviceSpec>>,
    pub seed: u64,
}

/// Typed, validated construction of a [`RunConfig`] — the flag/JSON
/// string fields are filled from the enum labels, so a built config
/// always passes the eager `parse_*` validation.
#[derive(Debug, Clone)]
pub struct RunConfigBuilder {
    cfg: RunConfig,
}

impl RunConfigBuilder {
    pub fn gws(mut self, gws: u64) -> Self {
        self.cfg.gws = Some(gws);
        self
    }

    pub fn scheduler(mut self, scheduler: SchedulerKind) -> Self {
        self.cfg.scheduler = scheduler;
        self
    }

    pub fn mode(mut self, mode: ExecMode) -> Self {
        self.cfg.mode = match mode {
            ExecMode::Roi => "roi".into(),
            ExecMode::Binary => "binary".into(),
        };
        self
    }

    pub fn optimizations(mut self, opts: Optimizations) -> Self {
        self.cfg.init_overlap = opts.init_overlap;
        self.cfg.buffer_flags = opts.buffer_flags;
        self.cfg.estimate_refine = opts.estimate_refine;
        self
    }

    pub fn mask_policy(mut self, policy: MaskPolicy) -> Self {
        self.cfg.mask_policy = policy.label().into();
        self
    }

    pub fn contention(mut self, contention: ContentionModel) -> Self {
        self.cfg.contention = contention.label().into();
        self
    }

    pub fn reps(mut self, reps: usize) -> Self {
        self.cfg.reps = reps;
        self
    }

    pub fn devices(mut self, devices: Vec<DeviceSpec>) -> Self {
        self.cfg.devices = Some(devices);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Validate and return the config (same checks `from_json` runs).
    pub fn build(self) -> Result<RunConfig> {
        let cfg = self.cfg;
        if cfg.reps < 2 {
            bail!("'reps' must be >= 2 (warm-up + measured runs), got {}", cfg.reps);
        }
        if cfg.gws == Some(0) {
            bail!("'gws' must be a positive integer");
        }
        if let Some(devices) = &cfg.devices {
            if devices.is_empty() {
                bail!("'devices' must not be empty");
            }
            for d in devices {
                if d.power <= 0.0 {
                    bail!("device power must be positive, got {}", d.power);
                }
            }
        }
        cfg.parse_bench()?;
        cfg.parse_mode()?;
        cfg.parse_mask_policy()?;
        cfg.parse_contention()?;
        Ok(cfg)
    }
}

impl RunConfig {
    /// Start a validated builder from the per-bench defaults.
    pub fn builder(bench: BenchId) -> RunConfigBuilder {
        RunConfigBuilder { cfg: Self::for_bench(bench) }
    }

    /// Sensible default experiment for one benchmark.
    pub fn for_bench(bench: BenchId) -> Self {
        Self {
            bench: bench.label().to_lowercase(),
            gws: None,
            scheduler: SchedulerKind::HGuided { params: HGuidedParams::optimized_paper() },
            mode: "roi".into(),
            init_overlap: true,
            buffer_flags: true,
            estimate_refine: false,
            mask_policy: MaskPolicy::Fixed.label().into(),
            contention: ContentionModel::View.label().into(),
            reps: 50,
            devices: None,
            seed: 1,
        }
    }

    /// Parse from a JSON document, e.g.
    /// ```json
    /// {
    ///   "bench": "ray2", "gws": 123456, "mode": "binary",
    ///   "scheduler": {"kind": "hguided", "m": [1, 15, 30], "k": [3.5, 1.5, 1]},
    ///   "init_overlap": false, "reps": 20,
    ///   "devices": [{"class": "cpu", "power": 0.2}, {"class": "gpu", "power": 1.0}]
    /// }
    /// ```
    pub fn from_json(v: &Json) -> Result<Self> {
        let bench = v
            .get("bench")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("config missing 'bench'"))?
            .to_string();
        let mut cfg = Self::for_bench(parse_bench(&bench)?);
        cfg.bench = bench;
        if let Some(g) = v.get("gws") {
            cfg.gws = Some(g.as_u64().ok_or_else(|| anyhow!("'gws' must be a positive integer"))?);
        }
        if let Some(s) = v.get("scheduler") {
            cfg.scheduler = parse_scheduler(s)?;
        }
        if let Some(m) = v.get("mode") {
            cfg.mode = m.as_str().ok_or_else(|| anyhow!("'mode' must be a string"))?.into();
        }
        if let Some(b) = v.get("init_overlap") {
            cfg.init_overlap = b.as_bool().ok_or_else(|| anyhow!("'init_overlap' must be bool"))?;
        }
        if let Some(b) = v.get("buffer_flags") {
            cfg.buffer_flags = b.as_bool().ok_or_else(|| anyhow!("'buffer_flags' must be bool"))?;
        }
        if let Some(b) = v.get("estimate_refine") {
            cfg.estimate_refine =
                b.as_bool().ok_or_else(|| anyhow!("'estimate_refine' must be bool"))?;
        }
        if let Some(m) = v.get("mask_policy") {
            cfg.mask_policy =
                m.as_str().ok_or_else(|| anyhow!("'mask_policy' must be a string"))?.into();
        }
        if let Some(c) = v.get("contention") {
            cfg.contention =
                c.as_str().ok_or_else(|| anyhow!("'contention' must be a string"))?.into();
        }
        if let Some(r) = v.get("reps") {
            cfg.reps =
                r.as_u64().ok_or_else(|| anyhow!("'reps' must be a positive integer"))? as usize;
            if cfg.reps < 2 {
                bail!("'reps' must be >= 2 (warm-up + measured runs), got {}", cfg.reps);
            }
        }
        if let Some(s) = v.get("seed") {
            cfg.seed = s.as_u64().ok_or_else(|| anyhow!("'seed' must be a positive integer"))?;
        }
        if let Some(d) = v.get("devices") {
            cfg.devices = Some(parse_devices(d)?);
        }
        cfg.parse_mode()?; // validate eagerly
        cfg.parse_mask_policy()?;
        cfg.parse_contention()?;
        Ok(cfg)
    }

    pub fn from_json_file(path: &std::path::Path) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        let v = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        Self::from_json(&v)
    }

    pub fn parse_bench(&self) -> Result<BenchId> {
        parse_bench(&self.bench)
    }

    pub fn parse_mode(&self) -> Result<ExecMode> {
        match self.mode.to_lowercase().as_str() {
            "roi" => Ok(ExecMode::Roi),
            "binary" => Ok(ExecMode::Binary),
            m => bail!("unknown mode '{m}' (roi|binary)"),
        }
    }

    /// The pipeline mask-selection policy this config asks for (feeds
    /// `PipelineSpec::with_mask_policy` when the config drives a
    /// pipeline run).
    pub fn parse_mask_policy(&self) -> Result<MaskPolicy> {
        MaskPolicy::parse(&self.mask_policy).ok_or_else(|| {
            anyhow!(
                "unknown mask_policy '{}' \
                 (fixed|min-energy|min-time|energy-under-deadline)",
                self.mask_policy
            )
        })
    }

    /// The co-execution contention scope this config asks for (feeds
    /// `EngineBuilder::contention` for pipeline runs).
    pub fn parse_contention(&self) -> Result<ContentionModel> {
        ContentionModel::parse(&self.contention)
            .ok_or_else(|| anyhow!("unknown contention '{}' (view|pool)", self.contention))
    }

    pub fn optimizations(&self) -> Optimizations {
        Optimizations {
            init_overlap: self.init_overlap,
            buffer_flags: self.buffer_flags,
            estimate_refine: self.estimate_refine,
        }
    }

    /// Build the configured engine.
    pub fn engine(&self) -> Result<crate::engine::Engine> {
        let bench = crate::benchsuite::Bench::new(self.parse_bench()?);
        let mut b = crate::engine::Engine::builder(bench)
            .scheduler(self.scheduler.clone())
            .mode(self.parse_mode()?)
            .optimizations(self.optimizations())
            .mask_policy(self.parse_mask_policy()?)
            .contention(self.parse_contention()?);
        if let Some(gws) = self.gws {
            b = b.gws(gws);
        }
        if let Some(devices) = &self.devices {
            b = b.devices(devices.clone());
        }
        Ok(b.build())
    }

    #[deprecated(note = "use RunConfig::engine()")]
    pub fn build_engine(&self) -> Result<crate::engine::Engine> {
        self.engine()
    }
}

/// Parse a benchmark name (case-insensitive; "ray"/"ray1"/"ray2").
pub fn parse_bench(name: &str) -> Result<BenchId> {
    Ok(match name.to_lowercase().as_str() {
        "gaussian" => BenchId::Gaussian,
        "binomial" => BenchId::Binomial,
        "nbody" => BenchId::NBody,
        "ray" | "ray1" => BenchId::Ray1,
        "ray2" => BenchId::Ray2,
        "mandelbrot" => BenchId::Mandelbrot,
        n => bail!("unknown benchmark '{n}'"),
    })
}

/// Parse a scheduler spec: either a string shorthand ("static",
/// "static-rev", "dynamic:128", "hguided", "hguided-opt") or an object
/// `{"kind": "hguided", "m": [...], "k": [...]}`.
pub fn parse_scheduler(v: &Json) -> Result<SchedulerKind> {
    if let Some(s) = v.as_str() {
        return parse_scheduler_str(s);
    }
    let kind = v
        .get("kind")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("scheduler object missing 'kind'"))?;
    match kind {
        "dynamic" => {
            let n = v
                .get("chunks")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("dynamic scheduler needs 'chunks'"))?;
            Ok(SchedulerKind::Dynamic { n_chunks: n })
        }
        "hguided" => {
            let params = match parse_mk_arrays(v, "hguided")? {
                Some((m, k)) => HGuidedParams { min_mult: m, k },
                None => HGuidedParams::optimized_paper(),
            };
            Ok(SchedulerKind::HGuided { params })
        }
        "adaptive" => {
            let mut params = match parse_mk_arrays(v, "adaptive")? {
                Some((m, k)) => AdaptiveParams { min_mult: m, k, pessimism: 0.25 },
                None => AdaptiveParams::default_paper(),
            };
            if let Some(p) = v.get("pessimism") {
                params.pessimism = p
                    .as_f64()
                    .ok_or_else(|| anyhow!("'pessimism' must be a number"))?;
            }
            if !(0.0..1.0).contains(&params.pessimism) {
                bail!("'pessimism' must be in [0, 1), got {}", params.pessimism);
            }
            Ok(SchedulerKind::Adaptive { params })
        }
        _ => parse_scheduler_str(kind),
    }
}

/// The shared `"m": [..], "k": [..]` pair of the hguided/adaptive object
/// forms: both arrays, or neither (caller falls back to paper defaults).
fn parse_mk_arrays(v: &Json, kind: &str) -> Result<Option<(Vec<u64>, Vec<f64>)>> {
    let arr_u64 = |k: &str| -> Option<Vec<u64>> {
        v.get(k)?.as_arr()?.iter().map(Json::as_u64).collect()
    };
    let arr_f64 = |k: &str| -> Option<Vec<f64>> {
        v.get(k)?.as_arr()?.iter().map(Json::as_f64).collect()
    };
    match (arr_u64("m"), arr_f64("k")) {
        (Some(m), Some(k)) => Ok(Some((m, k))),
        (None, None) => Ok(None),
        _ => bail!("{kind} scheduler needs both 'm' and 'k' (or neither)"),
    }
}

/// String shorthand accepted by both JSON configs and CLI flags.
pub fn parse_scheduler_str(s: &str) -> Result<SchedulerKind> {
    let s = s.to_lowercase();
    Ok(match s.as_str() {
        "static" => SchedulerKind::Static,
        "static-rev" | "static_rev" | "staticrev" => SchedulerKind::StaticRev,
        "hguided" => SchedulerKind::HGuided { params: HGuidedParams::default_paper() },
        "hguided-opt" | "hguided_opt" => {
            SchedulerKind::HGuided { params: HGuidedParams::optimized_paper() }
        }
        "adaptive" => SchedulerKind::Adaptive { params: AdaptiveParams::default_paper() },
        _ => {
            if let Some(n) = s.strip_prefix("dynamic:").or_else(|| s.strip_prefix("dyn:")) {
                SchedulerKind::Dynamic {
                    n_chunks: n.parse().map_err(|_| anyhow!("bad chunk count '{n}'"))?,
                }
            } else {
                bail!(
                    "unknown scheduler '{s}' \
                     (static|static-rev|dynamic:N|hguided|hguided-opt|adaptive)"
                )
            }
        }
    })
}

fn parse_devices(v: &Json) -> Result<Vec<DeviceSpec>> {
    let arr = v.as_arr().ok_or_else(|| anyhow!("'devices' must be an array"))?;
    let mut out = Vec::with_capacity(arr.len());
    for d in arr {
        let class = match d
            .get("class")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("device missing 'class'"))?
            .to_lowercase()
            .as_str()
        {
            "cpu" => DeviceClass::Cpu,
            "igpu" => DeviceClass::IGpu,
            "gpu" | "dgpu" => DeviceClass::DGpu,
            c => bail!("unknown device class '{c}' (cpu|igpu|gpu)"),
        };
        let power = d
            .get("power")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("device missing 'power'"))?;
        if power <= 0.0 {
            bail!("device power must be positive, got {power}");
        }
        out.push(DeviceSpec { class, power });
    }
    if out.is_empty() {
        bail!("'devices' must not be empty");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_parse() {
        let c = RunConfig::for_bench(BenchId::Mandelbrot);
        assert_eq!(c.parse_bench().unwrap(), BenchId::Mandelbrot);
        assert_eq!(c.parse_mode().unwrap(), ExecMode::Roi);
        assert!(c.optimizations().init_overlap);
        assert!(c.engine().is_ok());
    }

    #[test]
    fn builder_validates_and_labels_roundtrip() {
        let c = RunConfig::builder(BenchId::Gaussian)
            .mode(ExecMode::Binary)
            .mask_policy(MaskPolicy::EnergyUnderDeadline)
            .contention(ContentionModel::Pool)
            .gws(4096)
            .reps(4)
            .seed(9)
            .build()
            .unwrap();
        assert_eq!(c.parse_mode().unwrap(), ExecMode::Binary);
        assert_eq!(c.parse_mask_policy().unwrap(), MaskPolicy::EnergyUnderDeadline);
        assert_eq!(c.parse_contention().unwrap(), ContentionModel::Pool);
        assert_eq!(c.gws, Some(4096));
        assert_eq!(c.seed, 9);
        let e = c.engine().unwrap();
        assert_eq!(e.mask_policy(), MaskPolicy::EnergyUnderDeadline);
        assert_eq!(e.contention(), ContentionModel::Pool);
        assert!(RunConfig::builder(BenchId::Gaussian).reps(1).build().is_err());
        assert!(RunConfig::builder(BenchId::Gaussian).gws(0).build().is_err());
        assert!(RunConfig::builder(BenchId::Gaussian).devices(vec![]).build().is_err());
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_build_engine_forwards() {
        let c = RunConfig::for_bench(BenchId::Gaussian);
        assert_eq!(
            c.build_engine().unwrap().mask_policy(),
            c.engine().unwrap().mask_policy()
        );
    }

    #[test]
    fn json_with_overrides() {
        let json = Json::parse(
            r#"{
            "bench": "ray2",
            "gws": 123456,
            "mode": "binary",
            "init_overlap": false,
            "reps": 5,
            "scheduler": {"kind": "hguided", "m": [1, 15, 30], "k": [3.5, 1.5, 1]},
            "devices": [
                {"class": "cpu", "power": 0.2},
                {"class": "gpu", "power": 1.0}
            ]
        }"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&json).unwrap();
        assert_eq!(c.parse_bench().unwrap(), BenchId::Ray2);
        assert_eq!(c.gws, Some(123456));
        assert_eq!(c.parse_mode().unwrap(), ExecMode::Binary);
        assert!(!c.optimizations().init_overlap);
        assert!(c.optimizations().buffer_flags, "default true");
        assert!(!c.optimizations().estimate_refine, "extension defaults off");
        let refined = Json::parse(r#"{"bench": "gaussian", "estimate_refine": true}"#).unwrap();
        assert!(RunConfig::from_json(&refined).unwrap().optimizations().estimate_refine);
        assert_eq!(c.parse_mask_policy().unwrap(), MaskPolicy::Fixed, "default fixed");
        assert_eq!(c.parse_contention().unwrap(), ContentionModel::View, "default view");
        let doc = r#"{"bench": "gaussian", "contention": "pool"}"#;
        let pooled = RunConfig::from_json(&Json::parse(doc).unwrap()).unwrap();
        assert_eq!(pooled.parse_contention().unwrap(), ContentionModel::Pool);
        assert_eq!(
            pooled.engine().unwrap().contention(),
            ContentionModel::Pool,
            "contention scope wired into the engine"
        );
        let doc = r#"{"bench": "gaussian", "mask_policy": "energy-under-deadline"}"#;
        let masked = RunConfig::from_json(&Json::parse(doc).unwrap()).unwrap();
        assert_eq!(masked.parse_mask_policy().unwrap(), MaskPolicy::EnergyUnderDeadline);
        // The knob is wired through to the engine, not just validated.
        let engine = masked.engine().unwrap();
        assert_eq!(engine.mask_policy(), MaskPolicy::EnergyUnderDeadline);
        assert_eq!(c.scheduler.label(), "HGuided opt");
        let devs = c.devices.unwrap();
        assert_eq!(devs.len(), 2);
        assert_eq!(devs[1].class, DeviceClass::DGpu);
    }

    #[test]
    fn scheduler_shorthands() {
        assert_eq!(parse_scheduler_str("static").unwrap(), SchedulerKind::Static);
        assert_eq!(parse_scheduler_str("Static-Rev").unwrap(), SchedulerKind::StaticRev);
        assert_eq!(
            parse_scheduler_str("dynamic:128").unwrap(),
            SchedulerKind::Dynamic { n_chunks: 128 }
        );
        assert_eq!(parse_scheduler_str("hguided-opt").unwrap().label(), "HGuided opt");
        assert_eq!(parse_scheduler_str("adaptive").unwrap().label(), "Adaptive");
        assert!(parse_scheduler_str("fifo").is_err());
    }

    #[test]
    fn adaptive_object_form_parses() {
        let v = Json::parse(
            r#"{"kind": "adaptive", "m": [1, 10, 20], "k": [3.0, 1.5, 1.0],
                "pessimism": 0.4}"#,
        )
        .unwrap();
        let kind = parse_scheduler(&v).unwrap();
        match kind {
            SchedulerKind::Adaptive { params } => {
                assert_eq!(params.min_mult, vec![1, 10, 20]);
                assert_eq!(params.pessimism, 0.4);
            }
            other => panic!("wrong kind {other:?}"),
        }
        let bad = Json::parse(r#"{"kind": "adaptive", "pessimism": 1.5}"#).unwrap();
        assert!(parse_scheduler(&bad).is_err());
    }

    #[test]
    fn bad_inputs_rejected() {
        assert!(parse_bench("sorting").is_err());
        let bad_mode = Json::parse(r#"{"bench": "gaussian", "mode": "speedrun"}"#).unwrap();
        assert!(RunConfig::from_json(&bad_mode).is_err());
        let bad_dev = Json::parse(
            r#"{"bench": "gaussian", "devices": [{"class": "cpu", "power": -1}]}"#,
        )
        .unwrap();
        assert!(RunConfig::from_json(&bad_dev).is_err());
        let bad_sched =
            Json::parse(r#"{"bench": "gaussian", "scheduler": {"kind": "dynamic"}}"#).unwrap();
        assert!(RunConfig::from_json(&bad_sched).is_err());
        let bad_reps = Json::parse(r#"{"bench": "gaussian", "reps": 1}"#).unwrap();
        assert!(RunConfig::from_json(&bad_reps).is_err(), "reps < 2 rejected");
        let bad_mask = Json::parse(r#"{"bench": "gaussian", "mask_policy": "fastest"}"#).unwrap();
        assert!(RunConfig::from_json(&bad_mask).is_err(), "mask policy validated eagerly");
        let bad_contention =
            Json::parse(r#"{"bench": "gaussian", "contention": "global"}"#).unwrap();
        assert!(RunConfig::from_json(&bad_contention).is_err(), "contention validated eagerly");
    }
}

//! Inflection study (Fig.-6 style): when is co-execution worth it on a
//! time-constrained commodity system?
//!
//! Sweeps problem size for one benchmark, prints the single-GPU vs
//! HGuided co-execution curves for binary and ROI modes at each runtime
//! optimization level, and reports the break-even points — the paper's
//! "it must exceed ~15 ms (ROI) / ~1.75 s (binary)" rule of thumb.
//!
//! ```bash
//! cargo run --release --example inflection_study [bench] [reps]
//! ```

use enginecl::config::parse_bench;
use enginecl::engine::experiments::{self, OptLevel};

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "gaussian".into());
    let reps: usize =
        std::env::args().nth(2).map(|s| s.parse()).transpose()?.unwrap_or(6);
    let id = parse_bench(&name)?;

    println!("inflection study: {} ({} reps/point)\n", id.label(), reps);
    let rows = experiments::fig6(id, reps);

    // Curves per (mode, opts), ROI first.
    for mode in ["roi", "binary"] {
        println!("-- {mode} mode --");
        println!(
            "{:>12} {:>12} {:>12} {:>12} {:>12} {:>12} {:>12}",
            "gws", "single(s)", "co/base", "co/+init", "co/+buf", "win@base", "win@+buf"
        );
        let gws_values: Vec<u64> = {
            let mut v: Vec<u64> = rows
                .iter()
                .filter(|r| r.mode == mode && r.opts == "baseline")
                .map(|r| r.gws)
                .collect();
            v.dedup();
            v
        };
        for gws in gws_values {
            let get = |opts: &str| {
                rows.iter()
                    .find(|r| r.mode == mode && r.opts == opts && r.gws == gws)
                    .expect("row")
            };
            let b = get("baseline");
            let i = get("+init");
            let a = get("+init+buffers");
            println!(
                "{:>12} {:>12.4} {:>12.4} {:>12.4} {:>12.4} {:>12} {:>12}",
                gws,
                b.single_gpu_s,
                b.coexec_s,
                i.coexec_s,
                a.coexec_s,
                if b.coexec_s < b.single_gpu_s { "yes" } else { "-" },
                if a.coexec_s < a.single_gpu_s { "yes" } else { "-" },
            );
        }
        println!();
    }

    println!("-- break-even points --");
    let infl = experiments::inflections(&rows);
    for i in &infl {
        match (i.gws, i.time_s) {
            (Some(g), Some(t)) => {
                println!(
                    "{:>8} {:>15}: gws* = {:>12.0}, single-GPU t* = {:.4}s",
                    i.mode, i.opts, g, t
                )
            }
            _ => println!("{:>8} {:>15}: co-execution never wins on this ladder", i.mode, i.opts),
        }
    }
    let init_gain = experiments::inflection_improvement(&infl, OptLevel::None, OptLevel::Init);
    let buf_gain = experiments::inflection_improvement(&infl, OptLevel::Init, OptLevel::All);
    println!(
        "\ninflection improvements: init {:.1}% (paper avg 7.5%), buffers {:.1}% (paper avg 17.4%)",
        init_gain * 100.0,
        buf_gain * 100.0
    );
    Ok(())
}

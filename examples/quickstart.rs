//! Quickstart: co-execute one benchmark across the modelled commodity
//! testbed and print the paper's three metrics.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use enginecl::benchsuite::{Bench, BenchId};
use enginecl::engine::Engine;
use enginecl::metrics;
use enginecl::scheduler::{HGuidedParams, SchedulerKind};
use enginecl::types::{ExecMode, Optimizations};

fn main() {
    // Tier-1 usage: pick a program, pick a scheduler, run.
    let bench = Bench::new(BenchId::Mandelbrot);
    println!(
        "program: {} ({} work-items, lws {})",
        bench.props.name, bench.default_gws, bench.props.lws
    );

    let engine = Engine::new(bench)
        .with_scheduler(SchedulerKind::HGuided { params: HGuidedParams::optimized_paper() })
        .with_mode(ExecMode::Roi)
        .with_optimizations(Optimizations::ALL);

    // The paper's protocol: repeated runs, first discarded as warm-up.
    let reps = engine.run_reps(20);
    println!("co-execution ROI time: {:.3}s ± {:.3}", reps.time.mean, reps.time.ci95());
    println!("balance (T_first/T_last): {:.3}", reps.balance.mean);

    // Baseline: the fastest device alone (paper: single GPU).
    let standalone = engine.standalone_times(8);
    println!(
        "standalone times  CPU {:.2}s  iGPU {:.2}s  GPU {:.2}s",
        standalone[0], standalone[1], standalone[2]
    );
    let s_max = metrics::max_speedup(&standalone);
    let s = metrics::speedup(standalone[2], reps.time.mean);
    println!(
        "speedup {:.3} of max {:.3} -> efficiency {:.3} (paper mean: 0.84)",
        s,
        s_max,
        metrics::efficiency(s, s_max)
    );
}

//! Scheduler comparison on one benchmark: the seven Fig.-3 configurations
//! side by side, plus an ablation against an ideal (zero-overhead) driver
//! to separate algorithmic imbalance from driver overheads.
//!
//! ```bash
//! cargo run --release --example scheduler_comparison [bench]
//! ```

use enginecl::benchsuite::Bench;
use enginecl::cldriver::DriverProfile;
use enginecl::config::parse_bench;
use enginecl::engine::Engine;
use enginecl::metrics;
use enginecl::scheduler::SchedulerKind;

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "ray2".into());
    let bench = Bench::new(parse_bench(&name)?);
    println!(
        "scheduler comparison: {} (gws {}, {} groups, irregularity {:.2})\n",
        bench.props.name,
        bench.default_gws,
        bench.groups(bench.default_gws),
        bench.profile.peak_to_mean()
    );

    let reps = 20;
    let base = Engine::new(bench.clone());
    let standalone = base.standalone_times(8);
    let s_max = metrics::max_speedup(&standalone);
    println!(
        "standalone: CPU {:.2}s  iGPU {:.2}s  GPU {:.2}s  (S_max {:.3})\n",
        standalone[0], standalone[1], standalone[2], s_max
    );

    println!(
        "{:<14}{:>10}{:>10}{:>10}{:>10}{:>12}",
        "scheduler", "time(s)", "speedup", "eff", "balance", "pkgs/run"
    );
    for kind in SchedulerKind::fig3_configs() {
        let commodity = base.clone().with_scheduler(kind.clone()).run_reps(reps);
        let s = metrics::speedup(standalone[2], commodity.time.mean);
        println!(
            "{:<14}{:>10.3}{:>10.3}{:>10.3}{:>10.3}{:>12.1}",
            kind.label(),
            commodity.time.mean,
            s,
            metrics::efficiency(s, s_max),
            commodity.balance.mean,
            commodity.mean_packages
        );
    }

    println!("\n-- ablation: ideal driver (no overheads) isolates pure load balancing --");
    println!("{:<14}{:>10}{:>10}", "scheduler", "time(s)", "balance");
    for kind in SchedulerKind::fig3_configs() {
        let ideal = base
            .clone()
            .with_scheduler(kind.clone())
            .with_driver(DriverProfile::ideal())
            .run_reps(reps);
        println!("{:<14}{:>10.3}{:>10.3}", kind.label(), ideal.time.mean, ideal.balance.mean);
    }
    Ok(())
}

//! End-to-end driver (the repo's headline validation): REAL co-execution
//! of every AOT Pallas/HLO kernel through the full three-layer stack.
//!
//! For each of the five artifacts this:
//!   1. builds a real workload in rust (images, option books, bodies, rays),
//!   2. spawns one PJRT worker thread per modelled device (CPU/iGPU/GPU,
//!      speed-emulated), each owning its own PJRT client + executable,
//!   3. co-executes the kernel under the HGuided-optimized scheduler,
//!   4. verifies sampled outputs against the rust oracles,
//!   5. reports ROI time, balance and speedup vs the GPU-only baseline.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! make artifacts && cargo run --release --example coexec_e2e
//! ```

use anyhow::Result;
use enginecl::benchsuite::{data::Problem, BenchId};
use enginecl::engine::pjrt::{run_coexec, PjrtRunConfig};
use enginecl::runtime::ArtifactDir;

fn main() -> Result<()> {
    let artifacts = ArtifactDir::open(ArtifactDir::default_path())?;
    println!(
        "artifacts: {} ({} kernels)",
        artifacts.dir.display(),
        artifacts.manifest.benches.len()
    );

    // Problem sizes in tiles, kept CI-friendly; NBody is fixed at N by the
    // artifact (2048 bodies = 8 tiles).
    let plans: &[(BenchId, u64)] = &[
        (BenchId::Mandelbrot, 64),
        (BenchId::Gaussian, 32),
        (BenchId::Binomial, 8),
        (BenchId::NBody, 8),
        (BenchId::Ray1, 64),
        (BenchId::Ray2, 64),
    ];

    let mut failures = 0usize;
    println!(
        "\n{:<12}{:>7}{:>10}{:>9}{:>9}{:>9}{:>10}{:>8}",
        "bench", "tiles", "gws", "init(s)", "roi(s)", "balance", "speedup", "verify"
    );
    for &(id, tiles) in plans {
        let entry = artifacts.manifest.entry(id.artifact_name())?;
        let problem = Problem::new(id, tiles, entry, 42)?;

        let cfg = PjrtRunConfig::testbed();
        let report = run_coexec(id, &problem, &artifacts, &cfg)?;
        let solo = run_coexec(id, &problem, &artifacts, &PjrtRunConfig::gpu_only())?;
        failures += report.verify_failures;

        println!(
            "{:<12}{:>7}{:>10}{:>9.3}{:>9.3}{:>9.3}{:>10.3}{:>8}",
            id.label(),
            tiles,
            problem.gws,
            report.init_s,
            report.roi_s,
            report.balance(),
            solo.roi_s / report.roi_s,
            if report.verify_failures == 0 { "OK" } else { "FAIL" }
        );
        for d in &report.devices {
            println!(
                "             {:<5} P={:<4} pkgs={:<3} tiles={:<4} finish={:.3}s",
                d.label, d.power, d.packages, d.tiles, d.finish_s
            );
        }
    }

    if failures == 0 {
        println!("\nE2E OK: all sampled outputs match the rust oracles across all kernels.");
        Ok(())
    } else {
        anyhow::bail!("E2E FAILED: {failures} verification mismatches")
    }
}
